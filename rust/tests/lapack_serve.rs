//! LAPACK factorizations served as dependency-DAG workloads.
//!
//! The acceptance pins of the graph-aware dispatch engine:
//! * served `Request::Dgeqrf/Dgetrf/Dpotrf` return factors matching the
//!   host references at 1e-10 across shapes, including non-4-aligned;
//! * a factorization executes as *many dependent pool jobs* — pinned by
//!   pool job counts and by the obs node events: every successor's
//!   release cycle is at or after its predecessors' completion cycles;
//! * repeated same-shape factorizations ride the shared program cache;
//! * responses and their event-log `sim_signature`s are deterministic
//!   across runs, under replay-batch coalescing, and on a routed fabric;
//! * a factorization tenant and a DGEMM-flooding tenant both complete
//!   with isolated-coordinator results under the cycle-cost scheduler,
//!   with live cycle service on both lanes;
//! * the served DGEQRF response carries the Fig-1 flop attribution
//!   (DGEMM-dominated at representative size).

use redefine_blas::coordinator::{
    request::{factor_workload, mixed_lapack_workload},
    Coordinator, CoordinatorConfig, Request, Response,
};
use redefine_blas::engine::{Engine, EngineConfig, SchedPolicy};
use redefine_blas::lapack::{
    self, dgeqrf_profiled, dgetrf, dpotrf, expand::expand, default_nb, FactorKind, Factors,
    ProfiledOp,
};
use redefine_blas::noc::FabricConfig;
use redefine_blas::obs::{BufferSink, Event, EventKind};
use redefine_blas::pe::AeLevel;
use redefine_blas::util::{assert_allclose, Mat};
use std::sync::Arc;

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        ..CoordinatorConfig::default()
    }
}

/// The operand each factorization kind is served on (SPD for Cholesky).
fn operand(kind: FactorKind, n: usize, seed: u64) -> Mat {
    match kind {
        FactorKind::Chol => Mat::random_spd(n, seed),
        FactorKind::Qr | FactorKind::Lu => Mat::random(n, n, seed),
    }
}

fn factor_request(kind: FactorKind, a: Mat) -> Request {
    match kind {
        FactorKind::Qr => Request::Dgeqrf { a },
        FactorKind::Lu => Request::Dgetrf { a },
        FactorKind::Chol => Request::Dpotrf { a },
    }
}

/// Served factors must match the host reference element-wise at `tol`.
fn assert_factors_match_host(resp: &Response, kind: FactorKind, a: &Mat, tol: f64) {
    let f = resp.factor.as_ref().expect("factorization response carries factors");
    match (&f.factors, kind) {
        (Factors::Qr(got), FactorKind::Qr) => {
            let (want, _) = dgeqrf_profiled(a, default_nb(a.rows()));
            assert_allclose(got.a.as_slice(), want.a.as_slice(), tol);
            assert_allclose(&got.tau, &want.tau, tol);
        }
        (Factors::Lu(got), FactorKind::Lu) => {
            let (want, _) = dgetrf(a);
            assert_allclose(got.lu.as_slice(), want.lu.as_slice(), tol);
            assert_eq!(got.piv, want.piv, "pivot sequences must be identical");
        }
        (Factors::Chol(got), FactorKind::Chol) => {
            let (want, _) = dpotrf(a);
            assert_allclose(got.as_slice(), want.as_slice(), tol);
        }
        (other, _) => panic!("wrong factor payload for {kind:?}: {other:?}"),
    }
}

#[test]
fn served_factorizations_match_host_references() {
    // Conformance across kinds and shapes, including non-4-aligned orders
    // (the kernel-side dims round up; the factor values are exact because
    // they resolve host-side, exactly like the Level-1/2 serving path).
    for kind in [FactorKind::Qr, FactorKind::Lu, FactorKind::Chol] {
        for n in [12usize, 23, 24, 37] {
            let a = operand(kind, n, 1_000 + n as u64);
            let mut co = Coordinator::new(cfg());
            let resps = co.serve_batch(vec![factor_request(kind, a.clone())]);
            assert_eq!(resps.len(), 1);
            let r = &resps[0];
            assert_eq!(r.op, kind.op_name());
            assert_eq!(r.n, n);
            assert!(r.cycles > 0, "{kind:?} n={n}: DAG execution must cost cycles");
            assert!(r.energy_j.unwrap_or(0.0) > 0.0, "{kind:?} n={n}: energy accounted");
            assert_factors_match_host(r, kind, &a, 1e-10);
        }
    }
}

#[test]
fn sequential_and_batched_factor_serving_agree() {
    let a = Mat::random(24, 24, 7);
    let mut seq = Coordinator::new(cfg());
    let r_seq = seq.serve(vec![Request::Dgeqrf { a: a.clone() }]);
    let mut bat = Coordinator::new(cfg());
    let r_bat = bat.serve_batch(vec![Request::Dgeqrf { a: a.clone() }]);
    let (s, b) = (&r_seq[0], &r_bat[0]);
    assert_eq!(s.cycles, b.cycles, "sequential and batched DAG cost must agree");
    assert_eq!(s.energy_j, b.energy_j);
    let (fs, fb) = (s.factor.as_ref().unwrap(), b.factor.as_ref().unwrap());
    assert_eq!(fs.nodes, fb.nodes);
    assert_eq!(fs.makespan, fb.makespan);
    assert_factors_match_host(b, FactorKind::Qr, &a, 1e-10);
}

#[test]
fn factorization_executes_as_dependent_pool_jobs() {
    // n = 24, nb = 4 → 6 block columns → 6 panels + 15 updates = 21 DAG
    // nodes, every one a pool job.
    let n = 24;
    let a = Mat::random(n, n, 11);
    let expansion = expand(FactorKind::Qr, &a);
    let nodes = expansion.graph.len();
    assert!(nodes > 1, "a blocked factorization must expand to many nodes");

    let sink = Arc::new(BufferSink::new());
    let mut co = Coordinator::new(cfg());
    co.set_trace_sink(sink.clone());
    let resps = co.serve_batch(vec![Request::Dgeqrf { a }]);
    let f = resps[0].factor.as_ref().unwrap();
    assert_eq!(f.nodes, nodes);

    // Every DAG node ran as its own pool job of the matching kind.
    let jc = co.pool_job_counts();
    assert_eq!(
        (jc.gemm_tiles + jc.gemv + jc.level1) as usize,
        nodes,
        "each node is one pool job: {jc:?}"
    );
    assert!(jc.gemm_tiles > 0, "trailing updates are DGEMM jobs: {jc:?}");
    assert!(jc.gemv > 0, "QR panels are DGEMV jobs: {jc:?}");

    // The obs node events pin the dependency order: a node's release
    // cycle is the max of its predecessors' completion cycles, so every
    // successor was dispatched only after its predecessors completed.
    let events: Vec<Event> = sink.take();
    let mut released = vec![None; nodes];
    let mut completed = vec![None; nodes];
    for ev in &events {
        match ev.kind {
            EventKind::NodeReleased { node, .. } => released[node] = Some(ev.sim),
            EventKind::NodeCompleted { node, .. } => completed[node] = Some(ev.sim),
            _ => {}
        }
    }
    assert!(released.iter().all(Option::is_some), "every node must release");
    assert!(completed.iter().all(Option::is_some), "every node must complete");
    let mut gated = 0;
    for v in 0..nodes {
        for &u in &expansion.graph.node(v).preds {
            assert!(
                released[v].unwrap() >= completed[u].unwrap(),
                "node {v} released at {:?} before predecessor {u} completed at {:?}",
                released[v],
                completed[u]
            );
            gated += 1;
        }
        assert!(completed[v].unwrap() > released[v].unwrap(), "node {v} must cost cycles");
    }
    assert!(gated > 0, "the DAG must actually gate successors on predecessors");
    assert_eq!(resps[0].cycles, f.makespan, "off-fabric cost is the DAG makespan");
    // Independent trailing updates overlap: the DAG makespan is strictly
    // below the sum of per-node costs.
    let serial: u64 = (0..nodes).map(|v| completed[v].unwrap() - released[v].unwrap()).sum();
    assert!(
        f.makespan < serial,
        "independent updates must overlap: makespan {} vs serial sum {serial}",
        f.makespan
    );
}

#[test]
fn repeated_factorizations_hit_the_shared_program_cache() {
    // One factorization emits every kernel shape its DAG needs; the next
    // two factorizations of the same shape must ride those warm kernels
    // (distinct-seed operands — the kernels are keyed by shape, not data).
    let mut once = Coordinator::new(cfg());
    let _ = once.serve_batch(factor_workload(FactorKind::Qr, 1, 24, 50));
    let misses_once = once.cache_stats().misses;
    assert!(misses_once > 0);

    let mut thrice = Coordinator::new(cfg());
    let resps = thrice.serve_batch(factor_workload(FactorKind::Qr, 3, 24, 50));
    assert_eq!(resps.len(), 3);
    let cs = thrice.cache_stats();
    assert_eq!(
        cs.misses, misses_once,
        "repeats must add no new kernel emissions: {cs:?}"
    );
    assert!(
        cs.hits >= 2 * misses_once,
        "every repeated node must hit the warm kernel: {cs:?}"
    );
    // Warm factorizations still execute their DAG on the pool (3× jobs).
    let jc = thrice.pool_job_counts();
    let per = resps[0].factor.as_ref().unwrap().nodes;
    assert_eq!((jc.gemm_tiles + jc.gemv + jc.level1) as usize, 3 * per);
}

/// Serve `reqs` on a fresh coordinator with `cfg`, returning the responses
/// and the event log's deterministic signature.
fn run_traced(cfg: &CoordinatorConfig, reqs: Vec<Request>) -> (Vec<Response>, Vec<String>) {
    let sink = Arc::new(BufferSink::new());
    let mut co = Coordinator::new(cfg.clone());
    co.set_trace_sink(sink.clone());
    let resps = co.serve_batch(reqs);
    let sig = sink.take().iter().map(|e| e.sim_signature()).collect();
    (resps, sig)
}

#[test]
fn factor_serving_is_deterministic_across_runs_and_configs() {
    let mk = || mixed_lapack_workload(8, 24, 16, 99);
    for (name, cfg) in [
        ("plain", cfg()),
        ("replay-batch", CoordinatorConfig { replay_batch: Some(4), ..cfg() }),
        ("fabric-2", CoordinatorConfig { fabric: Some(FabricConfig::new(2)), ..cfg() }),
    ] {
        let (ra, sa) = run_traced(&cfg, mk());
        let (rb, sb) = run_traced(&cfg, mk());
        assert_eq!(ra.len(), rb.len(), "{name}");
        for (i, (x, y)) in ra.iter().zip(&rb).enumerate() {
            assert_eq!(x.op, y.op, "{name} request {i}");
            assert_eq!(x.cycles, y.cycles, "{name} request {i}: cycles must be reproducible");
            assert_eq!(x.energy_j, y.energy_j, "{name} request {i}");
            match (&x.factor, &y.factor) {
                (Some(fx), Some(fy)) => {
                    assert_eq!(fx.nodes, fy.nodes, "{name} request {i}");
                    assert_eq!(fx.makespan, fy.makespan, "{name} request {i}");
                }
                (None, None) => {}
                _ => panic!("{name} request {i}: factor payload mismatch"),
            }
        }
        assert_eq!(sa, sb, "{name}: the simulated event log must be bit-reproducible");
        assert!(
            sa.iter().any(|s| s.starts_with("node_released")),
            "{name}: node events must appear in the signature stream"
        );
    }
}

#[test]
fn fabric_routes_factor_nodes_and_prices_the_dag() {
    let fcfg = CoordinatorConfig { fabric: Some(FabricConfig::new(2)), ..cfg() };
    let a = Mat::random(24, 24, 33);
    let (resps, sigs) = run_traced(&fcfg, vec![Request::Dgeqrf { a: a.clone() }]);
    let r = &resps[0];
    let f = r.factor.as_ref().unwrap();
    assert_factors_match_host(r, FactorKind::Qr, &a, 1e-10);
    // On the mesh the response cost includes operand/result movement: it
    // can only be at or above the pure-compute DAG makespan.
    assert!(
        r.cycles >= f.makespan,
        "routed cost {} must not undercut the compute makespan {}",
        r.cycles,
        f.makespan
    );
    let routed = sigs.iter().filter(|s| s.starts_with("fabric_routed")).count();
    assert_eq!(routed, f.nodes, "every DAG node is routed on the fabric");
}

#[test]
fn factor_tenant_completes_against_dgemm_flood_under_cycle_scheduler() {
    // The proportional-service pin: a factorization tenant sharing the
    // engine with a DGEMM-flooding tenant under the cycle-cost DRR
    // scheduler must complete with exactly its isolated results, and both
    // lanes must show live dispatched-cycle service.
    let factor_work = factor_workload(FactorKind::Qr, 3, 24, 1);
    let mut iso = Coordinator::new(cfg());
    let iso_resps = iso.serve_batch(factor_work.clone());

    let engine = Engine::new(EngineConfig {
        workers: 2,
        sched: SchedPolicy::Cycles,
        ..EngineConfig::default()
    });
    let mut facs = engine.tenant(cfg());
    let mut flood = engine.tenant(cfg());
    let flood_work =
        redefine_blas::coordinator::request::repeated_gemm_workload(12, 32, 2);
    let (rf, rg) = std::thread::scope(|s| {
        let hf = s.spawn(|| facs.serve_batch(factor_work));
        let hg = s.spawn(|| flood.serve_batch(flood_work));
        (hf.join().expect("factor tenant"), hg.join().expect("flood tenant"))
    });
    assert_eq!(rg.len(), 12, "the flood must complete too");
    assert_eq!(rf.len(), iso_resps.len());
    for (i, (got, want)) in rf.iter().zip(&iso_resps).enumerate() {
        assert_eq!(got.cycles, want.cycles, "request {i}: contention must not change cost");
        assert_eq!(got.energy_j, want.energy_j, "request {i}");
        assert_eq!(
            got.factor.as_ref().unwrap().makespan,
            want.factor.as_ref().unwrap().makespan,
            "request {i}"
        );
    }
    // Both lanes were priced and served in the cycle currency.
    let service = engine.lane_service();
    assert_eq!(service.len(), 2);
    assert!(service.iter().all(|l| l.served_cost > 0), "both lanes must see service: {service:?}");
}

#[test]
fn dgeqrf_profile_reproduces_fig1_attribution() {
    // Fig 1 / §1: at representative size DGEQRF lives in DGEMM, with the
    // remainder in the panel's Level-2 work — served straight through the
    // factorization response.
    let n = 96;
    let mut co = Coordinator::new(cfg());
    let resps = co.serve_batch(vec![Request::Dgeqrf { a: Mat::random(n, n, 5) }]);
    let p = &resps[0].factor.as_ref().unwrap().profile;
    assert!(p.total() > 0);
    let dgemm = p.fraction(ProfiledOp::Dgemm);
    assert!(dgemm > 0.85, "DGEQRF must be DGEMM-dominated at n={n}: {dgemm:.3}");
    let level23 = dgemm + p.fraction(ProfiledOp::Dgemv) + p.fraction(ProfiledOp::Dger);
    assert!(
        level23 > 0.99,
        "~all DGEQRF flops land in DGEMM/DGEMV-class work: {level23:.4}"
    );
    // And the host-side profiler agrees with what the response reports.
    let host = lapack::dgeqrf_profiled(&Mat::random(n, n, 5), default_nb(n)).1;
    assert_eq!(host.total(), p.total());
}

#[test]
fn mixed_open_loop_arrivals_account_for_every_factorization() {
    use redefine_blas::coordinator::OpenLoopOptions;
    use redefine_blas::engine::traffic::{self, TrafficConfig};
    // A lapack-mixed open-loop stream: offered = served + shed, and every
    // served factorization carries its factor payload.
    let tcfg = TrafficConfig {
        rate_rps: 300.0,
        duration_ns: 40_000_000,
        seed: 6,
        max_n: 24,
        lapack_fraction: 0.4,
        lapack_n: 16,
        ..TrafficConfig::default()
    };
    let arrivals = traffic::generate(&tcfg);
    assert!(arrivals.iter().any(|a| matches!(a.req, Request::RandomFactor { .. })));
    let offered = arrivals.len();
    let mut co = Coordinator::new(cfg());
    let report = co.serve_open_loop(arrivals, &OpenLoopOptions::default());
    assert_eq!(report.stats.offered, offered);
    assert_eq!(report.stats.offered, report.stats.served + report.stats.shed);
    let factor_resps: Vec<_> = report
        .responses()
        .into_iter()
        .filter(|r| matches!(r.op, "dgeqrf" | "dgetrf" | "dpotrf"))
        .collect();
    assert!(!factor_resps.is_empty(), "some factorizations must be served");
    for r in factor_resps {
        let f = r.factor.as_ref().expect("served factorization carries factors");
        assert!(f.nodes > 1 && f.makespan > 0);
        assert!(r.cycles > 0);
    }
}
