//! Property-based tests over randomized inputs (dependency-free harness:
//! a deterministic xorshift case generator plays the role proptest would —
//! each property runs across dozens of generated cases and shrink-free
//! failures print the offending seed).

use redefine_blas::blas;
use redefine_blas::codegen::{gen_gemm_rect, GemmLayout};
use redefine_blas::coordinator::{Coordinator, CoordinatorConfig};
use redefine_blas::metrics::{measure_gemm, measure_level1, Routine};
use redefine_blas::noc::parallel_dgemm;
use redefine_blas::pe::{AeLevel, Pe, PeConfig};
use redefine_blas::util::{rel_fro_error, Mat, XorShift64};

/// Run a property across `cases` generated seeds.
fn forall(cases: u64, mut prop: impl FnMut(&mut XorShift64, u64)) {
    for seed in 0..cases {
        let mut rng = XorShift64::new(0xC0FFEE + seed * 7919);
        prop(&mut rng, seed);
    }
}

#[test]
fn prop_rect_gemm_matches_host_any_shape_any_level() {
    forall(24, |rng, seed| {
        let m = 4 * (1 + rng.below(5));
        let p = 4 * (1 + rng.below(5));
        let k = 4 * (1 + rng.below(5));
        let ae = AeLevel::ALL[rng.below(6)];
        let a = Mat::random(m, k, seed * 3 + 1);
        let b = Mat::random(k, p, seed * 3 + 2);
        let c = Mat::random(m, p, seed * 3 + 3);
        let layout = GemmLayout::rect(m, p, k);
        let prog = gen_gemm_rect(m, p, k, ae, &layout);
        let mut pe = Pe::new(PeConfig::paper(ae), layout.gm_words());
        pe.write_gm(0, &layout.pack(&a, &b, &c));
        let st = pe.run(&prog);
        let got = layout.unpack_c(&pe.gm, m, p);
        let want = blas::level3::dgemm_ref(&a, &b, &c);
        let err = rel_fro_error(got.as_slice(), want.as_slice());
        assert!(err < 1e-12, "seed {seed}: {m}x{p}x{k}@{ae}: err {err}");
        // Timing invariants.
        assert!(st.cycles >= st.instructions, "seed {seed}: issue width is 1");
        assert!(st.flops == 2 * (m * p * k) as u64, "seed {seed}: flop count");
    });
}

#[test]
fn prop_enhancements_never_slow_down() {
    forall(8, |rng, _| {
        let n = 4 * (2 + rng.below(6));
        let mut prev = u64::MAX;
        for ae in AeLevel::ALL {
            let cyc = measure_gemm(n, ae).latency();
            assert!(cyc <= prev, "n={n}: {ae} regressed ({cyc} > {prev})");
            prev = cyc;
        }
    });
}

#[test]
fn prop_alpha_at_least_one_and_decreasing_in_n() {
    // α = latency / DOT4-work ≥ 1 always (eq. 7 denominator is ideal work),
    // and approaches 1 monotonically-ish as n grows (fig 11(b)).
    for ae in [AeLevel::Ae2, AeLevel::Ae4, AeLevel::Ae5] {
        let mut prev = f64::INFINITY;
        for n in [20usize, 40, 60, 80, 100] {
            let m = measure_gemm(n, ae);
            let alpha = m.alpha();
            assert!(alpha >= 1.0, "{ae} n={n}: α {alpha} < 1");
            assert!(alpha <= prev + 0.05, "{ae} n={n}: α rising ({alpha} > {prev})");
            prev = alpha;
        }
    }
}

#[test]
fn prop_noc_speedup_bounded_by_tiles() {
    forall(6, |rng, seed| {
        let b = 2 + rng.below(3); // 2..4
        let n = b * 4 * (1 + rng.below(3));
        let a = Mat::random(n, n, seed + 100);
        let bm = Mat::random(n, n, seed + 200);
        let c = Mat::random(n, n, seed + 300);
        let r = parallel_dgemm(n, b, AeLevel::Ae5, &a, &bm, &c);
        let s = r.speedup();
        assert!(s > 0.5, "b={b} n={n}: speedup {s} collapsed");
        assert!(
            s <= (b * b) as f64 + 1e-9,
            "b={b} n={n}: superlinear speedup {s} impossible"
        );
    });
}

#[test]
fn prop_coordinator_values_equal_host_blas() {
    forall(10, |rng, seed| {
        let n = 5 + rng.below(40); // arbitrary, unaligned sizes
        let b = 1 + rng.below(3);
        let a = Mat::random(n, n, seed + 1);
        let bm = Mat::random(n, n, seed + 2);
        let c = Mat::random(n, n, seed + 3);
        let mut co = Coordinator::new(CoordinatorConfig {
            ae: AeLevel::ALL[1 + rng.below(5)], // AE1..AE5
            b,
            artifact_dir: "/nonexistent".into(),
            verify: false,
            ..CoordinatorConfig::default()
        });
        let r = co.dgemm(&a, &bm, &c);
        let want = blas::level3::dgemm_ref(&a, &bm, &c);
        let err = rel_fro_error(r.c.as_slice(), want.as_slice());
        assert!(err < 1e-12, "seed {seed} n={n} b={b}: err {err}");
    });
}

#[test]
fn prop_level1_numerics_and_memory_bound() {
    forall(12, |rng, seed| {
        let n = 4 * (1 + rng.below(64));
        let ae = AeLevel::ALL[rng.below(6)];
        for r in [Routine::Ddot, Routine::Daxpy, Routine::Dnrm2] {
            // measure_level1 asserts numerics internally.
            let m = measure_level1(r, n, ae);
            assert!(m.latency() > 0, "seed {seed} {r:?}");
            // Level-1 can never exceed the GM-bound: 2 words per element
            // through a 1-word/cycle port ⇒ FPC ≤ ~2 paper-flops/cycle.
            if n >= 64 {
                assert!(
                    m.paper_fpc() <= 3.5,
                    "seed {seed} {r:?} n={n}: implausible FPC {}",
                    m.paper_fpc()
                );
            }
        }
    });
}

#[test]
fn prop_strassen_winograd_gemm_agree() {
    forall(10, |rng, seed| {
        let n = 3 + rng.below(40);
        let a = Mat::random(n, n, seed + 11);
        let b = Mat::random(n, n, seed + 12);
        let g = blas::level3::dgemm_ref(&a, &b, &Mat::zeros(n, n));
        let s = blas::strassen_multiply(&a, &b);
        let w = blas::winograd_multiply(&a, &b);
        assert!(rel_fro_error(s.as_slice(), g.as_slice()) < 1e-9, "seed {seed} SMM n={n}");
        assert!(rel_fro_error(w.as_slice(), g.as_slice()) < 1e-9, "seed {seed} WMM n={n}");
    });
}

#[test]
fn prop_qr_factors_reconstruct() {
    forall(8, |rng, seed| {
        let m = 6 + rng.below(20);
        let n = 3 + rng.below(m.min(16));
        let a = Mat::random(m, n, seed + 21);
        let f = redefine_blas::lapack::dgeqrf_profiled(&a, 1 + rng.below(8)).0;
        let q = redefine_blas::lapack::form_q(&f);
        let r = f.r();
        let mut r_full = Mat::zeros(m, n);
        r_full.set_block(0, 0, &r);
        let qr = blas::level3::dgemm_ref(&q, &r_full, &Mat::zeros(m, n));
        assert!(
            rel_fro_error(qr.as_slice(), a.as_slice()) < 1e-10,
            "seed {seed}: QR reconstruct {m}x{n}"
        );
    });
}

#[test]
fn prop_lu_solve_random_systems() {
    forall(10, |rng, seed| {
        let n = 4 + rng.below(24);
        let a = Mat::random_spd(n, seed + 31);
        let x0 = XorShift64::new(seed + 32).vec(n);
        let b = blas::level2::dgemv_ref(&a, &x0, &vec![0.0; n]);
        let (f, _) = redefine_blas::lapack::dgetrf(&a);
        let x = f.solve(&b);
        for i in 0..n {
            assert!((x[i] - x0[i]).abs() < 1e-7, "seed {seed} n={n} i={i}");
        }
        let _ = rng.next_u64();
    });
}

#[test]
fn prop_sim_determinism() {
    // Identical runs must produce identical cycle counts and values — the
    // whole experimental methodology rests on this.
    let layout = GemmLayout::packed(24);
    let prog = gen_gemm_rect(24, 24, 24, AeLevel::Ae5, &layout);
    let a = Mat::random(24, 24, 41);
    let b = Mat::random(24, 24, 42);
    let c = Mat::random(24, 24, 43);
    let gm = layout.pack(&a, &b, &c);
    let mut first: Option<(u64, Vec<f64>)> = None;
    for _ in 0..3 {
        let mut pe = Pe::new(PeConfig::paper(AeLevel::Ae5), layout.gm_words());
        pe.write_gm(0, &gm);
        let st = pe.run(&prog);
        let out = layout.unpack_c(&pe.gm, 24, 24).as_slice().to_vec();
        match &first {
            None => first = Some((st.cycles, out)),
            Some((cyc, vals)) => {
                assert_eq!(*cyc, st.cycles, "nondeterministic timing");
                assert_eq!(vals, &out, "nondeterministic values");
            }
        }
    }
}
