//! Observability integration tests: attaching a trace sink must be
//! invisible in every simulated observable (sink-off bit-identity), the
//! captured event log of a warmed closed-loop run must be deterministic
//! run to run (including under replay batching and on a routed fabric),
//! per-request spans must close the open-loop latency accounting, the
//! snapshot structs must reproduce the scattered stat getters, and both
//! exporters must emit well-formed output (validated here with a
//! hand-rolled JSON parser — the crate stays dependency-free).

use redefine_blas::coordinator::request::{random_workload, repeated_gemm_workload};
use redefine_blas::coordinator::{
    Coordinator, CoordinatorConfig, OpenLoopOptions, OpenLoopOutcome, Response,
};
use redefine_blas::engine::traffic::{self, ArrivalKind, TrafficConfig};
use redefine_blas::engine::{Engine, EngineConfig};
use redefine_blas::noc::FabricConfig;
use redefine_blas::obs::{
    response_traces, to_chrome, to_jsonl, BufferSink, EventKind, NullSink, TraceSink,
};
use redefine_blas::pe::AeLevel;
use std::sync::Arc;

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        ..CoordinatorConfig::default()
    }
}

/// Exact (bit-level) equality of two response streams, values and costs.
fn assert_identical(a: &[Response], b: &[Response]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.op, y.op);
        assert_eq!(x.n, y.n);
        assert_eq!(x.cycles, y.cycles, "{} n={}: cycles drifted", x.op, x.n);
        assert_eq!(x.energy_j, y.energy_j);
        assert_eq!(x.matrix, y.matrix);
        assert_eq!(x.vector, y.vector);
        assert_eq!(x.scalar, y.scalar);
    }
}

/// Serve `reqs` twice on a fresh traced coordinator — once to warm every
/// kernel (cold-kernel events are dropped), once measured — and return
/// the warm run's deterministic signatures plus its responses.
fn traced_run(config: CoordinatorConfig, reqs: Vec<redefine_blas::coordinator::Request>) -> (Vec<String>, Vec<Response>) {
    let mut co = Coordinator::new(config);
    let sink = Arc::new(BufferSink::new());
    co.set_trace_sink(sink.clone());
    let _ = co.serve_batch(reqs.clone());
    let _ = sink.take();
    let resps = co.serve_batch(reqs);
    (sink.take().iter().map(|e| e.sim_signature()).collect(), resps)
}

#[test]
fn sink_off_null_and_buffer_are_bit_identical() {
    let reqs = random_workload(10, 24, 5);
    let mut off = Coordinator::new(cfg());
    let mut null = Coordinator::new(cfg());
    null.set_trace_sink(Arc::new(NullSink) as Arc<dyn TraceSink>);
    let mut buf = Coordinator::new(cfg());
    let sink = Arc::new(BufferSink::new());
    buf.set_trace_sink(sink.clone());

    let r_off = off.serve_batch(reqs.clone());
    let r_null = null.serve_batch(reqs.clone());
    let r_buf = buf.serve_batch(reqs);
    assert_identical(&r_off, &r_null);
    assert_identical(&r_off, &r_buf);
    assert_eq!(format!("{:?}", off.cache_stats()), format!("{:?}", null.cache_stats()));
    assert_eq!(format!("{:?}", off.cache_stats()), format!("{:?}", buf.cache_stats()));
    assert_eq!(
        format!("{:?}", off.pool_job_counts()),
        format!("{:?}", buf.pool_job_counts()),
        "tracing changed pool job accounting"
    );
    assert!(!sink.take().is_empty(), "BufferSink captured nothing from a traced serve");
}

#[test]
fn sink_off_identity_holds_on_a_fabric() {
    let reqs = repeated_gemm_workload(6, 16, 42);
    let fab = || CoordinatorConfig { fabric: Some(FabricConfig::new(2)), ..cfg() };
    let mut off = Coordinator::new(fab());
    let mut traced = Coordinator::new(fab());
    let sink = Arc::new(BufferSink::with_host_clock());
    traced.set_trace_sink(sink.clone());

    let r_off = off.serve_batch(reqs.clone());
    let r_traced = traced.serve_batch(reqs);
    assert_identical(&r_off, &r_traced);
    assert_eq!(off.fabric_stats(), traced.fabric_stats(), "tracing changed fabric telemetry");
    assert!(
        sink.take().iter().any(|e| matches!(e.kind, EventKind::FabricRouted { .. })),
        "fabric serving emitted no FabricRouted events"
    );
}

#[test]
fn warmed_event_log_is_deterministic() {
    let reqs = random_workload(10, 24, 5);
    let (sa, ra) = traced_run(cfg(), reqs.clone());
    let (sb, rb) = traced_run(cfg(), reqs);
    assert!(!sa.is_empty());
    assert_eq!(sa, sb, "two identically warmed runs diverged in their event logs");
    assert_identical(&ra, &rb);
}

#[test]
fn warmed_event_log_is_deterministic_under_replay_batching() {
    let reqs = repeated_gemm_workload(8, 16, 77);
    let config = CoordinatorConfig { replay_batch: Some(8), ..cfg() };
    let (sa, ra) = traced_run(config.clone(), reqs.clone());
    let (sb, rb) = traced_run(config, reqs);
    assert_eq!(sa, sb, "replay batching broke event-log determinism");
    assert_identical(&ra, &rb);
    assert!(
        sa.iter().any(|s| s.contains("tier=batched")),
        "warm coalesced serve must execute on the batched tier"
    );
}

#[test]
fn warmed_event_log_is_deterministic_on_a_fabric() {
    let reqs = repeated_gemm_workload(6, 16, 99);
    let config = CoordinatorConfig { fabric: Some(FabricConfig::new(2)), ..cfg() };
    let (sa, ra) = traced_run(config.clone(), reqs.clone());
    let (sb, rb) = traced_run(config, reqs);
    assert_eq!(sa, sb, "fabric routing broke event-log determinism");
    assert_identical(&ra, &rb);
    assert!(sa.iter().any(|s| s.contains("fabric_routed")));
}

#[test]
fn open_loop_spans_close_the_latency_accounting() {
    let mut co = Coordinator::new(CoordinatorConfig { queue_depth: Some(2), ..cfg() });
    let sink = Arc::new(BufferSink::with_host_clock());
    co.set_trace_sink(sink.clone());
    let arrivals = traffic::generate(&TrafficConfig {
        kind: ArrivalKind::Burst { size: 8 },
        rate_rps: 4000.0,
        duration_ns: 40_000_000,
        seed: 42,
        max_n: 20,
        ..TrafficConfig::default()
    });
    let offered = arrivals.len();
    let report = co.serve_open_loop(arrivals, &OpenLoopOptions::default());
    assert_eq!(report.stats.offered, offered);
    assert_eq!(report.stats.served + report.stats.shed, offered, "open-loop lost arrivals");
    assert!(report.stats.shed > 0, "depth-2 queue under a burst flood must shed");

    // The event log closes the same accounting: one Shed per rejection,
    // one Completed per served request.
    let events = sink.take();
    let shed = events.iter().filter(|e| matches!(e.kind, EventKind::Shed { .. })).count();
    let completed =
        events.iter().filter(|e| matches!(e.kind, EventKind::Completed { .. })).count();
    assert_eq!(shed, report.stats.shed);
    assert_eq!(completed, report.stats.served);

    // Per-request spans: queue + service must equal the outcome's split
    // exactly, request by request (matched on the admission seq).
    let traces = response_traces(&events);
    assert_eq!(traces.len(), report.stats.served);
    let mut by_seq = std::collections::HashMap::new();
    for o in &report.outcomes {
        if let OpenLoopOutcome::Served { seq, queue_ns, service_ns, .. } = o {
            by_seq.insert(*seq, (*queue_ns, *service_ns));
        }
    }
    for t in &traces {
        assert!(t.completed, "admitted request never completed in the log");
        let seq = t.seq.expect("served spans carry the admission seq");
        let (queue_ns, service_ns) = by_seq[&seq];
        assert_eq!(t.queue_ns, queue_ns);
        assert_eq!(t.service_ns, service_ns);
        assert_eq!(t.total_ns, queue_ns + service_ns);
        assert!(t.dispatched > 0 || t.cache_hits > 0, "span shows no work for seq {seq}");
    }
}

#[test]
fn tenant_snapshot_reproduces_the_scattered_stats() {
    let mut co = Coordinator::new(cfg());
    let _ = co.serve_batch(random_workload(8, 24, 3));
    let snap = co.snapshot();
    assert_eq!(format!("{:?}", snap.cache), format!("{:?}", co.cache_stats()));
    assert_eq!(format!("{:?}", snap.jobs), format!("{:?}", co.pool_job_counts()));
    assert_eq!(snap.pool_size, co.pool_size());
    assert_eq!(format!("{:?}", snap.batch), format!("{:?}", co.last_batch_stats()));
    assert!(snap.open_loop.is_none(), "no open-loop run happened");
    assert!(snap.fabric.is_none(), "no fabric configured");
}

#[test]
fn engine_snapshot_reproduces_the_engine_getters() {
    let engine = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() });
    let mut tenant = engine.tenant(cfg());
    let _ = tenant.serve_batch(repeated_gemm_workload(3, 16, 9));
    let es = engine.snapshot();
    assert_eq!(es.workers, engine.worker_count());
    assert_eq!(es.tenants, engine.tenant_count());
    assert_eq!(format!("{:?}", es.sched), format!("{:?}", engine.sched()));
    assert_eq!(format!("{:?}", es.cache), format!("{:?}", engine.cache_stats()));
    assert_eq!(format!("{:?}", es.jobs), format!("{:?}", engine.pool_job_counts()));
    assert_eq!(format!("{:?}", es.lanes), format!("{:?}", engine.lane_service()));
    assert!(es.fabric.is_none());
}

#[test]
fn jsonl_export_lines_parse_and_pair_admission_with_completion() {
    let mut co = Coordinator::new(cfg());
    let sink = Arc::new(BufferSink::new());
    co.set_trace_sink(sink.clone());
    let _ = co.serve_batch(random_workload(8, 24, 3));
    let groups = vec![(0usize, sink.take())];
    let out = to_jsonl(&groups);
    assert!(!out.is_empty());

    let mut admitted = std::collections::HashSet::new();
    let mut completed = 0usize;
    for line in out.lines() {
        let obj = Parser::parse(line);
        let Some(Json::Str(ev)) = get(&obj, "ev") else {
            panic!("JSONL line without an `ev` tag: {line}")
        };
        assert!(get(&obj, "tenant").is_some(), "line missing tenant: {line}");
        match ev.as_str() {
            "admitted" => {
                for key in ["req", "seq", "op", "n", "bytes"] {
                    assert!(get(&obj, key).is_some(), "admitted line missing `{key}`: {line}");
                }
                let Some(Json::Num(req)) = get(&obj, "req") else { panic!("req not numeric") };
                admitted.insert(*req as u64);
            }
            "completed" => {
                for key in ["req", "queue_ns", "service_ns", "cycles"] {
                    assert!(get(&obj, key).is_some(), "completed line missing `{key}`: {line}");
                }
                let Some(Json::Num(req)) = get(&obj, "req") else { panic!("req not numeric") };
                assert!(admitted.contains(&(*req as u64)), "completed an unadmitted request");
                completed += 1;
            }
            _ => {}
        }
    }
    assert_eq!(admitted.len(), 8);
    assert_eq!(completed, 8);
}

#[test]
fn chrome_export_is_valid_json_with_x_and_m_phases_only() {
    let mut co =
        Coordinator::new(CoordinatorConfig { fabric: Some(FabricConfig::new(2)), ..cfg() });
    let sink = Arc::new(BufferSink::with_host_clock());
    co.set_trace_sink(sink.clone());
    let _ = co.serve_batch(repeated_gemm_workload(4, 16, 11));
    let groups = vec![(0usize, sink.take())];
    let chrome = to_chrome(&groups);

    let doc = Parser::parse(&chrome);
    let Some(Json::Arr(entries)) = get(&doc, "traceEvents") else {
        panic!("chrome trace must be an object with a traceEvents array")
    };
    assert!(!entries.is_empty());
    let mut slices = 0usize;
    for e in entries {
        let Some(Json::Str(ph)) = get(e, "ph") else { panic!("trace entry without a phase") };
        assert!(ph == "X" || ph == "M", "unexpected trace phase {ph:?}");
        if ph == "X" {
            slices += 1;
            for key in ["name", "pid", "tid", "ts", "dur"] {
                assert!(get(e, key).is_some(), "X slice missing `{key}`");
            }
        } else {
            assert!(get(e, "name").is_some(), "metadata entry missing `name`");
        }
    }
    assert!(slices > 0, "chrome trace has no duration slices");
}

// ---------------------------------------------------------------------------
// Minimal recursive-descent JSON parser, just enough to validate the
// exporters without pulling in a dependency. Panics (failing the test) on
// any malformed input.

#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

fn get<'a>(obj: &'a Json, key: &str) -> Option<&'a Json> {
    match obj {
        Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
        _ => None,
    }
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn parse(text: &str) -> Json {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        let v = p.value();
        p.ws();
        assert_eq!(p.i, p.s.len(), "trailing bytes after the JSON value");
        v
    }

    fn ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> u8 {
        *self.s.get(self.i).expect("unexpected end of JSON input")
    }

    fn expect(&mut self, lit: &str) {
        assert!(
            self.s[self.i..].starts_with(lit.as_bytes()),
            "expected `{lit}` at byte {}",
            self.i
        );
        self.i += lit.len();
    }

    fn value(&mut self) -> Json {
        self.ws();
        match self.peek() {
            b'{' => self.obj(),
            b'[' => self.arr(),
            b'"' => Json::Str(self.string()),
            b't' => {
                self.expect("true");
                Json::Bool(true)
            }
            b'f' => {
                self.expect("false");
                Json::Bool(false)
            }
            b'n' => {
                self.expect("null");
                Json::Null
            }
            _ => self.num(),
        }
    }

    fn string(&mut self) -> String {
        self.expect("\"");
        let mut out = String::new();
        loop {
            match self.peek() {
                b'"' => {
                    self.i += 1;
                    return out;
                }
                b'\\' => {
                    self.i += 1;
                    let esc = self.peek();
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])
                                .expect("\\u needs 4 hex digits");
                            let cp = u32::from_str_radix(hex, 16).expect("bad \\u escape");
                            self.i += 4;
                            out.push(char::from_u32(cp).expect("surrogates unused here"));
                        }
                        other => panic!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    let rest = std::str::from_utf8(&self.s[self.i..]).expect("valid UTF-8");
                    let ch = rest.chars().next().expect("unterminated string");
                    assert!((ch as u32) >= 0x20, "unescaped control character in string");
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn num(&mut self) -> Json {
        let start = self.i;
        while self.i < self.s.len()
            && matches!(self.s[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.s[start..self.i]).expect("ascii number");
        Json::Num(txt.parse().unwrap_or_else(|_| panic!("bad JSON number `{txt}`")))
    }

    fn arr(&mut self) -> Json {
        self.expect("[");
        let mut out = Vec::new();
        self.ws();
        if self.peek() == b']' {
            self.i += 1;
            return Json::Arr(out);
        }
        loop {
            out.push(self.value());
            self.ws();
            match self.peek() {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Json::Arr(out);
                }
                other => panic!("expected `,` or `]` in array, got `{}`", other as char),
            }
        }
    }

    fn obj(&mut self) -> Json {
        self.expect("{");
        let mut out = Vec::new();
        self.ws();
        if self.peek() == b'}' {
            self.i += 1;
            return Json::Obj(out);
        }
        loop {
            self.ws();
            let key = self.string();
            self.ws();
            self.expect(":");
            let val = self.value();
            out.push((key, val));
            self.ws();
            match self.peek() {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Json::Obj(out);
                }
                other => panic!("expected `,` or `}}` in object, got `{}`", other as char),
            }
        }
    }
}
