//! Integration tests: the three layers composed.
//!
//! These tests require `make artifacts` to have run (they are skipped with
//! a message when the artifact directory is absent, so `cargo test` works
//! in a fresh checkout too).

use redefine_blas::blas;
use redefine_blas::coordinator::{request::Request, Coordinator, CoordinatorConfig, ValueSource};
use redefine_blas::pe::AeLevel;
use redefine_blas::runtime::Runtime;
use redefine_blas::util::{assert_allclose, rel_fro_error, Mat, XorShift64};

fn artifact_dir() -> Option<String> {
    if cfg!(not(feature = "pjrt")) {
        // The stub runtime can never execute artifacts — even ones on disk.
        eprintln!("skipping: built without the `pjrt` feature (stub runtime)");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("gemm_n8.hlo.txt").exists() {
        Some(dir.to_string_lossy().into_owned())
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn xla_gemm_matches_host_all_sizes() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).expect("PJRT client");
    for n in [8usize, 20, 40, 60, 80, 100] {
        let a = Mat::random(n, n, n as u64);
        let b = Mat::random(n, n, n as u64 + 1);
        let c = Mat::random(n, n, n as u64 + 2);
        let got = rt.gemm(&a, &b, &c).expect("gemm");
        let want = blas::level3::dgemm_ref(&a, &b, &c);
        let err = rel_fro_error(got.as_slice(), want.as_slice());
        assert!(err < 1e-13, "n={n}: XLA gemm err {err}");
    }
}

#[test]
fn xla_gemv_and_level1_match_host() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).expect("PJRT client");
    let mut rng = XorShift64::new(5150);

    let n = 40;
    let a = Mat::random(n, n, 1);
    let x = rng.vec(n);
    let y = rng.vec(n);
    let got = rt.gemv(&a, &x, &y).expect("gemv");
    assert_allclose(&got, &blas::level2::dgemv_ref(&a, &x, &y), 1e-13);

    let m = 256;
    let xv = rng.vec(m);
    let yv = rng.vec(m);
    let d = rt.dot(&xv, &yv).expect("dot");
    assert!((d - blas::level1::ddot(&xv, &yv)).abs() < 1e-10);

    let ax = rt.axpy(2.5, &xv, &yv).expect("axpy");
    let mut want = yv.clone();
    blas::level1::daxpy(2.5, &xv, &mut want);
    assert_allclose(&ax, &want, 1e-13);

    let nr = rt.nrm2(&xv).expect("nrm2");
    assert!((nr - blas::level1::dnrm2(&xv)).abs() < 1e-10);
}

#[test]
fn xla_qr_panel_matches_lapack_lite() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).expect("PJRT client");
    let n = 32;
    let a = Mat::random(n, n, 77);
    let (out, tau) = rt.qr_panel(&a).expect("qr_panel");
    // Compare against the host DGEQR2's first panel step.
    let f = redefine_blas::lapack::dgeqr2(&a);
    assert!((tau - f.tau[0]).abs() < 1e-12, "tau {tau} vs {}", f.tau[0]);
    // Column 0 (beta + v tail) must match.
    for i in 0..n {
        assert!(
            (out[(i, 0)] - f.a[(i, 0)]).abs() < 1e-10,
            "col0[{i}]: {} vs {}",
            out[(i, 0)],
            f.a[(i, 0)]
        );
    }
}

#[test]
fn coordinator_prefers_xla_and_verifies() {
    let Some(dir) = artifact_dir() else { return };
    let mut co = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: dir,
        verify: true, // cross-checks XLA vs PE-sim internally
        ..CoordinatorConfig::default()
    });
    assert!(co.has_xla());
    let n = 20;
    let a = Mat::random(n, n, 8);
    let b = Mat::random(n, n, 9);
    let c = Mat::random(n, n, 10);
    let r = co.dgemm(&a, &b, &c);
    assert_eq!(r.source, ValueSource::Xla);
    let want = blas::level3::dgemm_ref(&a, &b, &c);
    assert!(rel_fro_error(r.c.as_slice(), want.as_slice()) < 1e-13);
}

#[test]
fn coordinator_off_shape_falls_back_to_pe_sim() {
    let Some(dir) = artifact_dir() else { return };
    let mut co = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: dir,
        verify: true,
        ..CoordinatorConfig::default()
    });
    let n = 36; // no artifact for 36
    let a = Mat::random(n, n, 11);
    let b = Mat::random(n, n, 12);
    let c = Mat::zeros(n, n);
    let r = co.dgemm(&a, &b, &c);
    assert_eq!(r.source, ValueSource::PeSim);
    let want = blas::level3::dgemm_ref(&a, &b, &c);
    assert!(rel_fro_error(r.c.as_slice(), want.as_slice()) < 1e-12);
}

#[test]
fn serve_loop_mixed_sources() {
    let Some(dir) = artifact_dir() else { return };
    let mut co = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: dir,
        verify: true,
        ..CoordinatorConfig::default()
    });
    let reqs = vec![
        Request::RandomDgemm { n: 20, seed: 1 }, // artifact hit
        Request::RandomDgemm { n: 24, seed: 2 }, // miss → PE sim
        Request::Ddot { x: vec![1.0; 256], y: vec![2.0; 256] }, // artifact hit
    ];
    let resps = co.serve(reqs);
    assert_eq!(resps[0].source, ValueSource::Xla);
    assert_eq!(resps[1].source, ValueSource::PeSim);
    assert_eq!(resps[2].source, ValueSource::Xla);
    assert_eq!(resps[2].scalar, Some(512.0));
}

#[test]
fn timing_is_independent_of_value_source() {
    // Co-simulation invariant: swapping the value source must not change
    // the simulated latency (timing comes from the PE/NoC models only).
    let Some(dir) = artifact_dir() else { return };
    let n = 20;
    let a = Mat::random(n, n, 21);
    let b = Mat::random(n, n, 22);
    let c = Mat::zeros(n, n);
    let mut with_xla = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: dir,
        verify: true,
        ..CoordinatorConfig::default()
    });
    let mut without = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        ..CoordinatorConfig::default()
    });
    let r1 = with_xla.dgemm(&a, &b, &c);
    let r2 = without.dgemm(&a, &b, &c);
    assert_eq!(r1.source, ValueSource::Xla);
    assert_eq!(r2.source, ValueSource::PeSim);
    assert_eq!(r1.makespan, r2.makespan, "timing must not depend on value source");
    assert_allclose(r1.c.as_slice(), r2.c.as_slice(), 1e-12);
}
