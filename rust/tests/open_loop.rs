//! Open-loop serving tests: traffic-generator determinism and rate
//! properties, closed-loop equivalence of the admission/completion state
//! machine (zero-time arrivals ≡ `serve_batch`), deterministic and
//! sustained overload shedding (explicit rejections, never silent drops),
//! and SLO accounting.

use redefine_blas::coordinator::{
    request::{random_workload, Request},
    Coordinator, CoordinatorConfig, OpenLoopOptions, OpenLoopOutcome, Response, ShedReason,
};
use redefine_blas::engine::traffic::{self, Arrival, ArrivalKind, TrafficConfig};
use redefine_blas::pe::AeLevel;

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "/nonexistent".into(),
        verify: false,
        ..CoordinatorConfig::default()
    }
}

/// Field-by-field response equality (same as the serving tests).
fn assert_same_responses(lhs: &[&Response], rhs: &[Response]) {
    assert_eq!(lhs.len(), rhs.len());
    for (i, (a, b)) in lhs.iter().zip(rhs.iter()).enumerate() {
        assert_eq!(a.op, b.op, "request {i}");
        assert_eq!(a.n, b.n, "request {i}");
        assert_eq!(a.source, b.source, "request {i}");
        assert_eq!(a.cycles, b.cycles, "request {i}: simulated cycles must be identical");
        assert_eq!(a.energy_j, b.energy_j, "request {i}");
        assert_eq!(a.matrix, b.matrix, "request {i}: matrix payload");
        assert_eq!(a.vector, b.vector, "request {i}: vector payload");
        assert_eq!(a.scalar, b.scalar, "request {i}: scalar payload");
    }
}

/// `count` same-shape DGEMMs all due at t = 0 — the deterministic
/// simultaneous burst the shedding tests are built on: the driver resolves
/// every due arrival before admitting anything, so shed counts cannot
/// depend on host timing.
fn burst_at_zero(count: usize, n: usize) -> Vec<Arrival> {
    let mut arrivals = Vec::new();
    for i in 0..count {
        let req = Request::RandomDgemm { n, seed: i as u64 };
        arrivals.push(Arrival { seq: i, at_ns: 0, req });
    }
    arrivals
}

// ---------------------------------------------------------------------
// Traffic generator properties.
// ---------------------------------------------------------------------

#[test]
fn same_seed_reproduces_the_exact_arrival_sequence() {
    let cfg = TrafficConfig {
        rate_rps: 5_000.0,
        duration_ns: 20_000_000, // ~100 arrivals
        seed: 7,
        max_n: 24,
        ..TrafficConfig::default()
    };
    let a = traffic::generate(&cfg);
    let b = traffic::generate(&cfg);
    assert!(!a.is_empty());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.seq, y.seq);
        assert_eq!(x.at_ns, y.at_ns);
        // Request has no PartialEq (it carries matrices); its Debug form
        // prints every operand value, which pins payload determinism.
        assert_eq!(format!("{:?}", x.req), format!("{:?}", y.req));
    }
    let other = traffic::generate(&TrafficConfig { seed: 8, ..cfg });
    let same_times =
        a.len() == other.len() && a.iter().zip(&other).all(|(x, y)| x.at_ns == y.at_ns);
    assert!(!same_times, "a different seed must produce a different schedule");
}

#[test]
fn poisson_mean_inter_arrival_tracks_the_configured_rate() {
    let rate = 20_000.0;
    let cfg = TrafficConfig {
        rate_rps: rate,
        duration_ns: 2_000_000_000, // 2 s => ~40k arrivals
        seed: 99,
        ..TrafficConfig::default()
    };
    let times = traffic::arrival_times(&cfg);
    let expected = rate * cfg.duration_ns as f64 / 1e9;
    assert!(
        (times.len() as f64 - expected).abs() < 0.05 * expected,
        "arrival count {} should be within 5% of {expected}",
        times.len()
    );
    // Empirical mean gap over the observed span vs 1/rate.
    let span = (times[times.len() - 1] - times[0]) as f64;
    let mean_gap = span / (times.len() - 1) as f64;
    let want = 1e9 / rate;
    assert!(
        (mean_gap - want).abs() < 0.05 * want,
        "mean inter-arrival {mean_gap} ns should be within 5% of {want} ns"
    );
}

#[test]
fn burst_process_keeps_the_mean_rate() {
    let rate = 16_000.0;
    let cfg = TrafficConfig {
        kind: ArrivalKind::Burst { size: 8 },
        rate_rps: rate,
        duration_ns: 2_000_000_000,
        seed: 17,
        ..TrafficConfig::default()
    };
    let times = traffic::arrival_times(&cfg);
    assert_eq!(times.len() % 8, 0, "whole bursts only");
    for group in times.chunks(8) {
        assert!(group.iter().all(|&t| t == group[0]), "burst members share one timestamp");
    }
    let expected = rate * cfg.duration_ns as f64 / 1e9;
    // Burst epochs are Poisson at rate/size, so the request count is
    // noisier than the plain process — 10% is ~7 sigma here.
    assert!(
        (times.len() as f64 - expected).abs() < 0.10 * expected,
        "burst arrival count {} should be within 10% of {expected}",
        times.len()
    );
}

// ---------------------------------------------------------------------
// Closed-loop equivalence: the refactored state machine, driven by
// zero-time arrivals with shedding off, must reproduce serve_batch
// exactly — values, cycles, energy, and cache accounting.
// ---------------------------------------------------------------------

#[test]
fn zero_time_arrivals_match_serve_batch_exactly() {
    let reqs = random_workload(10, 28, 5);
    let window = CoordinatorConfig { admission_window: Some(3), ..cfg() };

    let mut closed = Coordinator::new(window.clone());
    let want = closed.serve_batch(reqs.clone());

    let arrivals: Vec<Arrival> =
        reqs.into_iter().enumerate().map(|(i, req)| Arrival { seq: i, at_ns: 0, req }).collect();
    let mut open = Coordinator::new(window);
    let report = open.serve_open_loop(arrivals, &OpenLoopOptions::default());

    assert_eq!(report.stats.offered, 10);
    assert_eq!(report.stats.served, 10, "shedding is off: everything serves");
    assert_eq!(report.stats.shed, 0);
    assert_same_responses(&report.responses(), &want);
    assert_eq!(
        closed.cache_stats(),
        open.cache_stats(),
        "cache accounting must not depend on the serving mode"
    );
    let bs = open.last_batch_stats().expect("open-loop run records batch stats");
    assert_eq!(bs.requests, 10);
    assert_eq!(bs.shed, 0);
    assert!(bs.peak_staged <= 3, "admission window still bounds the open-loop pipeline");
}

#[test]
fn closed_loop_serve_batch_reports_zero_shed() {
    let mut co = Coordinator::new(cfg());
    co.serve_batch(random_workload(4, 20, 9));
    assert_eq!(co.last_batch_stats().expect("batch ran").shed, 0);
}

// ---------------------------------------------------------------------
// Overload: sheds are explicit, bounded, and fully accounted.
// ---------------------------------------------------------------------

#[test]
fn simultaneous_burst_sheds_deterministically() {
    // 24 heavy requests all due at t=0 against a window of 1 and a pending
    // cap of 2. The driver resolves every due arrival before admitting, so
    // exactly 2 are accepted and 22 shed — deterministically, regardless
    // of host timing.
    let mut co = Coordinator::new(CoordinatorConfig {
        admission_window: Some(1),
        queue_depth: Some(2),
        ..cfg()
    });
    let report = co.serve_open_loop(burst_at_zero(24, 16), &OpenLoopOptions::default());

    assert_eq!(report.stats.offered, 24);
    assert_eq!(report.outcomes.len(), 24, "zero silent drops: one outcome per arrival");
    assert_eq!(report.stats.served, 2, "pending cap 2 admits exactly two of a t=0 burst");
    assert_eq!(report.stats.shed, 22);
    assert!(report.stats.peak_pending <= 2);
    for (i, o) in report.outcomes.iter().enumerate() {
        assert_eq!(o.seq(), i, "outcomes sorted by arrival sequence");
        if let OpenLoopOutcome::Rejected { reason, op, n, .. } = o {
            assert_eq!(*reason, ShedReason::QueueDepth);
            assert_eq!((*op, *n), ("dgemm", 16), "rejections identify the shed request");
        }
    }
    let bs = co.last_batch_stats().expect("open-loop run records batch stats");
    assert_eq!((bs.requests, bs.shed), (2, 22));
}

#[test]
fn sustained_overload_sheds_explicitly_and_tail_stays_bounded() {
    // Offered load far beyond capacity: 300 DGEMMs 2 µs apart (~0.6 ms of
    // arrivals) against a cold engine whose first kernel emission alone
    // takes longer than the whole arrival window. The depth cap must shed
    // most of them; every arrival still gets exactly one outcome, and the
    // non-shed p99 is bounded by the run's wall clock (no wedged request).
    let mut co = Coordinator::new(CoordinatorConfig {
        admission_window: Some(2),
        queue_depth: Some(4),
        ..cfg()
    });
    let offered = 300;
    let arrivals: Vec<Arrival> = (0..offered)
        .map(|i| Arrival {
            seq: i,
            at_ns: 2_000 * i as u64,
            req: Request::RandomDgemm { n: 24, seed: i as u64 },
        })
        .collect();
    let t0 = std::time::Instant::now();
    let report = co.serve_open_loop(arrivals, &OpenLoopOptions { slo_total_ns: Some(0) });
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let s = &report.stats;

    assert_eq!(report.outcomes.len(), offered, "zero silent drops");
    assert_eq!(s.served + s.shed, offered);
    assert!(s.served >= 1, "overload must degrade, not wedge");
    assert!(s.shed > 0, "offered >> capacity must shed: {s:?}");
    assert!(s.peak_pending <= 4, "pending queue bounded by the depth cap");
    assert!(s.served < offered / 2, "most of a 2x+ overload must shed, not queue: {s:?}");
    assert!(s.total.p99 <= wall_ns, "p99 cannot exceed the run itself");
    assert!(s.total.p50 <= s.total.p95 && s.total.p95 <= s.total.p99);
    assert_eq!(s.total.count, s.served as u64, "latency recorded for served requests only");
    assert_eq!(s.slo_violations, s.served, "a 0 ns SLO flags every served request");
}

#[test]
fn byte_cap_sheds_with_its_own_reason() {
    // A byte budget of 1 sheds every arrival that finds the pending queue
    // nonempty (any DGEMM's packed image is far bigger); the empty-queue
    // escape still accepts, so the run serves some and rejects the rest
    // with the QueueBytes reason.
    let mut co = Coordinator::new(CoordinatorConfig {
        admission_window: Some(1),
        shed_after_bytes: Some(1),
        ..cfg()
    });
    let report = co.serve_open_loop(burst_at_zero(12, 12), &OpenLoopOptions::default());
    assert_eq!(report.stats.offered, 12);
    assert_eq!(report.stats.served + report.stats.shed, 12);
    assert!(report.stats.shed > 0, "the byte cap must shed a t=0 burst");
    for o in &report.outcomes {
        if let OpenLoopOutcome::Rejected { reason, .. } = o {
            assert_eq!(*reason, ShedReason::QueueBytes);
        }
    }
}

#[test]
fn unloaded_run_serves_everything_without_slo_violations() {
    // Light load, generous SLO: every arrival serves, nothing sheds, and
    // the SLO counter stays at zero.
    let mut co = Coordinator::new(CoordinatorConfig {
        admission_window: Some(4),
        queue_depth: Some(64),
        ..cfg()
    });
    let tcfg = TrafficConfig {
        rate_rps: 200.0,
        duration_ns: 50_000_000, // ~10 arrivals over 50 ms
        seed: 4,
        max_n: 16,
        hot_fraction: 1.0,
        hot_n: 12,
        ..TrafficConfig::default()
    };
    let arrivals = traffic::generate(&tcfg);
    let offered = arrivals.len();
    let report =
        co.serve_open_loop(arrivals, &OpenLoopOptions { slo_total_ns: Some(60_000_000_000) });
    assert_eq!(report.stats.offered, offered);
    assert_eq!(report.stats.served, offered);
    assert_eq!(report.stats.shed, 0);
    assert_eq!(report.stats.slo_violations, 0, "a 60 s SLO is never violated here");
    assert_eq!(report.stats.total.count, offered as u64);
}
