//! Paper-shape regression tests: the simulated system must keep
//! reproducing the *shape* of every table and figure — who wins, by what
//! factor, where the knees fall. These are the acceptance criteria of the
//! reproduction (see DESIGN.md §Calibration and EXPERIMENTS.md).

use redefine_blas::metrics::paper;
use redefine_blas::metrics::{gemm_sweep, measure_gemm, measure_gemv, measure_level1, Routine};
use redefine_blas::noc::parallel_dgemm;
use redefine_blas::pe::AeLevel;
use redefine_blas::platforms::{
    cpu::{model_dgemm, model_dgemv, CompilerSetup},
    db, CpuModel, GpuModel,
};
use redefine_blas::util::Mat;

/// One shared sweep for the table tests (n = 20..100 × AE0..AE5).
fn sweep() -> Vec<Vec<redefine_blas::metrics::Measurement>> {
    gemm_sweep(&paper::SIZES)
}

#[test]
fn tables_4_to_9_within_tolerance() {
    // Absolute latencies within 50% of the paper per cell (the model is a
    // substitute substrate, not the authors' RTL), trends exact.
    let s = sweep();
    for ai in 0..6 {
        for si in 0..5 {
            let got = s[ai][si].latency() as f64;
            let want = paper::LATENCY[ai][si] as f64;
            let ratio = got / want;
            assert!(
                (0.67..1.5).contains(&ratio),
                "table {} n={}: ratio {ratio:.2} ({got} vs {want})",
                4 + ai,
                paper::SIZES[si]
            );
        }
    }
}

#[test]
fn per_enhancement_improvements_match_paper_bands() {
    // The tables' actual claims: AE1 ≈ 41-43%, AE2 ≈ 34-38%, AE3 ≈ 10-17%,
    // AE4 ≈ 44-47%, AE5 ≈ 21-30%. Allow ±8 points of slack per transition.
    let s = sweep();
    for ai in 0..5 {
        for si in 0..5 {
            let meas = 1.0 - s[ai + 1][si].latency() as f64 / s[ai][si].latency() as f64;
            let want = paper::paper_improvement(ai, si);
            assert!(
                (meas - want).abs() < 0.08,
                "AE{}→AE{} n={}: improvement {meas:.3} vs paper {want:.3}",
                ai,
                ai + 1,
                paper::SIZES[si]
            );
        }
    }
}

#[test]
fn fig11a_overall_speedup_band() {
    let s = sweep();
    for si in 0..5 {
        let sp = s[0][si].latency() as f64 / s[5][si].latency() as f64;
        assert!(
            (5.5..10.5).contains(&sp),
            "n={}: AE0→AE5 speed-up {sp:.2} outside the paper band (~7-8.3)",
            paper::SIZES[si]
        );
    }
}

#[test]
fn fig11b_alpha_trends_to_one() {
    let mut alphas = Vec::new();
    for &n in &paper::SIZES {
        alphas.push(measure_gemm(n, AeLevel::Ae5).alpha());
    }
    for w in alphas.windows(2) {
        assert!(w[1] <= w[0] + 0.02, "α must fall with n: {alphas:?}");
    }
    assert!(alphas[4] < 2.6, "α at n=100 should approach 1: {alphas:?}");
    assert!(alphas[4] >= 1.0);
}

#[test]
fn fig11e_pct_peak_dips_at_ae2_then_recovers() {
    // The paper's most distinctive curve: %peak-FPC saturates ~54-62% at
    // AE1 (peak 2), *drops* when the DOT4 RDP raises the peak to 7, then
    // climbs back to ~74% at AE5.
    let n = 100;
    let pct: Vec<f64> =
        AeLevel::ALL.iter().map(|&ae| measure_gemm(n, ae).pct_peak_fpc()).collect();
    assert!(pct[1] > pct[2], "AE2 must dip below AE1 ({pct:?})");
    assert!(pct[5] > pct[2] && pct[5] > pct[3], "must recover by AE5 ({pct:?})");
    assert!(
        (55.0..80.0).contains(&pct[5]),
        "AE5 %peak {:.1} vs paper 74%",
        pct[5]
    );
    assert!((45.0..70.0).contains(&pct[1]), "AE1 %peak {:.1} vs paper ~54-62%", pct[1]);
}

#[test]
fn abstract_dgemv_and_ddot_efficiencies() {
    let mv = measure_gemv(100, AeLevel::Ae5).pct_peak_fpc();
    assert!(
        (25.0..55.0).contains(&mv),
        "DGEMV %peak {mv:.1} vs paper 40%"
    );
    let dd = measure_level1(Routine::Ddot, 1024, AeLevel::Ae5).pct_peak_fpc();
    assert!((12.0..30.0).contains(&dd), "DDOT %peak {dd:.1} vs paper 20%");
}

#[test]
fn gflops_per_watt_shape() {
    // Tables' energy column: AE1 < AE0 (more hardware), AE2 is the minimum
    // (RDP added, underused), AE5 is the maximum.
    let s = sweep();
    let gw: Vec<f64> = (0..6).map(|ai| s[ai][4].gflops_per_watt()).collect();
    assert!(gw[1] < gw[0], "AE1 must cost efficiency: {gw:?}");
    let min = gw.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!((gw[2] - min).abs() < 1e-9, "AE2 must be the minimum: {gw:?}");
    let max = gw.iter().cloned().fold(0.0, f64::max);
    assert!((gw[5] - max).abs() < 1e-9, "AE5 must be the maximum: {gw:?}");
    assert!((20.0..45.0).contains(&gw[5]), "AE5 Gflops/W {:.1} vs paper 35.7", gw[5]);
}

#[test]
fn fig2_cpu_story() {
    let hw = CpuModel::haswell();
    // gcc → icc → avx ladder at a large size.
    let g = model_dgemm(&hw, 2000, CompilerSetup::Gcc).pct_peak(&hw);
    let v = model_dgemm(&hw, 2000, CompilerSetup::IccAvx).pct_peak(&hw);
    assert!(g < v, "compiler ladder inverted");
    assert!((5.0..13.0).contains(&g), "gcc %peak {g:.1} (paper 10-11%)");
    assert!((13.0..20.0).contains(&v), "avx %peak {v:.1} (paper 15-17%)");
    // DGEMV far below.
    let mv = model_dgemv(&hw, 4000, CompilerSetup::IccAvx).pct_peak(&hw);
    assert!(mv < 9.0, "DGEMV %peak {mv:.1} (paper ~5%)");
}

#[test]
fn fig2_gpu_story() {
    let g = GpuModel::c2050();
    assert!((53.0..59.0).contains(&g.dgemm_pct_peak(4096)));
    assert!((3.0..7.0).contains(&g.dgemv_pct_peak(4096)));
}

#[test]
fn fig11j_pe_wins_by_paper_factors() {
    let pe_gw = measure_gemm(100, AeLevel::Ae5).gflops_per_watt();
    let ratios: std::collections::HashMap<_, _> =
        db::fig11j_ratios(pe_gw).into_iter().collect();
    // Paper: ~3x CSX700, ~10x FPGA, 7-139x GPUs, 40-140x CPUs. Our PE runs
    // ~20% slower than the paper's, so allow proportional slack.
    assert!((1.5..8.0).contains(&ratios["ClearSpeed CSX700"]));
    assert!((4.0..20.0).contains(&ratios["Altera Stratix-IV FPGA (LAPACKrc-class)"]));
    assert!((7.0..139.0).contains(&ratios["Nvidia Tesla C2050 (MAGMA)"]));
    assert!((25.0..400.0).contains(&ratios["Intel Core i7-4770 (Haswell)"]));
    for (name, r) in &ratios {
        assert!(*r > 1.0, "{name} must lose to the PE ({r:.2})");
    }
}

#[test]
fn fig12_scaling_shape() {
    // Speed-up grows with n and with the tile array, staying under b².
    let mk = |n: usize, b: usize| {
        let a = Mat::random(n, n, 601);
        let bm = Mat::random(n, n, 602);
        let c = Mat::random(n, n, 603);
        parallel_dgemm(n, b, AeLevel::Ae5, &a, &bm, &c).speedup()
    };
    let s2_small = mk(24, 2);
    let s2_big = mk(96, 2);
    let s3_big = mk(96, 3);
    let s4_big = mk(96, 4);
    assert!(s2_big > s2_small, "2x2 must improve with n: {s2_small:.2} → {s2_big:.2}");
    assert!(s2_big > 2.5 && s2_big <= 4.0 + 1e-9, "2x2 at n=96: {s2_big:.2}");
    assert!(s3_big > s2_big, "3x3 must beat 2x2: {s3_big:.2}");
    assert!(s4_big > s3_big, "4x4 must beat 3x3: {s4_big:.2}");
    assert!(s4_big <= 16.0 + 1e-9);
}
