"""L1 Pallas kernel: register-blocked DGEMM — the PE's compute hot-spot
re-thought for TPU-style tiling (DESIGN.md §Hardware-Adaptation).

Paper → Pallas mapping:

* the 4x4 register block held in the PE register file   → the kernel tile
  computed per grid step (``tile`` × ``tile``, MXU-shaped on real TPU);
* the Local Memory staging of A-strips / B-panels       → ``BlockSpec``
  HBM→VMEM schedules (one A tile, one B tile, the C accumulator tile);
* the DOT4 reconfigurable datapath                      → ``jnp.dot`` over
  the tile (lowered to the MXU systolic array on TPU);
* AE5's pre-fetch of the next iteration's block         → Pallas's
  automatic double-buffering of grid-step blocks.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the AOT artifact must run from the Rust runtime.
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.experimental import pallas as pl  # noqa: E402


def _pick_tile(n: int, preferred: int = 32) -> int:
    """Largest tile ≤ preferred that divides n (mirrors the paper's rule of
    blocking by the register file and falling back for residuals)."""
    for t in range(min(preferred, n), 0, -1):
        if n % t == 0:
            return t
    return 1


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] (+)= a[i,k] @ b[k,j], seeded with
    c[i,j] at k == 0 — the accumulation pattern of the paper's algorithm 3
    (BLOCK4ADD(BLOCK4MUL(A,B), C))."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...])


@functools.partial(jax.jit, static_argnames=("tile",))
def block_gemm(a, b, c, *, tile: int | None = None):
    """C' = A @ B + C with an explicitly blocked Pallas kernel.

    Works for rectangular (m×k)·(k×p) problems; every dimension must be
    divisible by its chosen tile (the coordinator pads, exactly like the PE
    path).
    """
    m, k = a.shape
    k2, p = b.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    assert c.shape == (m, p), f"C shape {c.shape}"
    tm = tile or _pick_tile(m)
    tp = tile or _pick_tile(p)
    tk = tile or _pick_tile(k)
    assert m % tm == 0 and p % tp == 0 and k % tk == 0, "tile must divide dims"
    grid = (m // tm, p // tp, k // tk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),  # A strip
            pl.BlockSpec((tk, tp), lambda i, j, kk: (kk, j)),  # B panel
            pl.BlockSpec((tm, tp), lambda i, j, kk: (i, j)),  # C seed
        ],
        out_specs=pl.BlockSpec((tm, tp), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, p), a.dtype),
        interpret=True,
    )(a, b, c)
