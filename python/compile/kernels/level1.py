"""L1 Pallas kernels: the Level-1 BLAS trio (ddot, daxpy; dnrm2 composes
ddot with a square root at L2).

The PE versions stream x/y through the Local Memory in 16-word groups and
reduce into four rotating DOT4 accumulators (codegen/level1.rs). Here a
grid step owns one chunk in VMEM; the dot kernel accumulates a scalar
across sequential grid steps — the same group-streamed reduction.
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.experimental import pallas as pl  # noqa: E402


def _pick_chunk(n: int, preferred: int = 64) -> int:
    for t in range(min(preferred, n), 0, -1):
        if n % t == 0:
            return t
    return 1


def _dot_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...] * y_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk",))
def chunked_dot(x, y, *, chunk: int | None = None):
    """x . y accumulated one VMEM chunk per grid step."""
    (n,) = x.shape
    assert y.shape == (n,)
    c = chunk or _pick_chunk(n)
    assert n % c == 0
    out = pl.pallas_call(
        _dot_kernel,
        grid=(n // c,),
        in_specs=[
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=True,
    )(x, y)
    return out[0]


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk",))
def chunked_axpy(alpha, x, y, *, chunk: int | None = None):
    """alpha * x + y, one VMEM chunk per grid step."""
    (n,) = x.shape
    assert y.shape == (n,)
    c = chunk or _pick_chunk(n)
    assert n % c == 0
    alpha_arr = jnp.asarray(alpha, x.dtype).reshape((1,))
    return pl.pallas_call(
        _axpy_kernel,
        grid=(n // c,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # alpha (resident scalar)
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(alpha_arr, x, y)
