"""L1 Pallas kernel: strip-mined DGEMV (y' = A @ x + y).

The PE kernel reduces four A rows at a time with DOT4s while x sits in the
Local Memory (codegen/gemv.rs); here a grid step owns a row strip in VMEM
and the whole x block, reducing with one ``dot`` per strip — the same
bandwidth-bound structure (A streamed exactly once).
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.experimental import pallas as pl  # noqa: E402


def _gemv_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], x_ref[...]) + y_ref[...]


def _pick_strip(n: int, preferred: int = 16) -> int:
    for t in range(min(preferred, n), 0, -1):
        if n % t == 0:
            return t
    return 1


@functools.partial(jax.jit, static_argnames=("strip",))
def strip_gemv(a, x, y, *, strip: int | None = None):
    """y' = A @ x + y with one grid step per row strip."""
    m, n = a.shape
    assert x.shape == (n,) and y.shape == (m,)
    s = strip or _pick_strip(m)
    assert m % s == 0, "strip must divide rows"
    return pl.pallas_call(
        _gemv_kernel,
        grid=(m // s,),
        in_specs=[
            pl.BlockSpec((s, n), lambda i: (i, 0)),  # A row strip
            pl.BlockSpec((n,), lambda i: (0,)),  # x (resident)
            pl.BlockSpec((s,), lambda i: (i,)),  # y strip
        ],
        out_specs=pl.BlockSpec((s,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x, y)
