"""Pure-jnp oracles for every Pallas kernel — the L1 correctness signal.

Each ``ref_*`` function is the mathematical definition of its kernel with no
Pallas involvement; pytest (and Hypothesis sweeps) assert the kernels match
these to tight tolerances across shapes and dtypes.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402


def ref_gemm(a, b, c):
    """C' = A @ B + C (the paper's DGEMM semantics)."""
    return a @ b + c


def ref_gemv(a, x, y):
    """y' = A @ x + y."""
    return a @ x + y


def ref_dot(x, y):
    """x . y"""
    return jnp.dot(x, y)


def ref_axpy(alpha, x, y):
    """alpha * x + y."""
    return alpha * x + y


def ref_nrm2(x):
    """||x||_2 (unscaled textbook form; inputs in tests are O(1))."""
    return jnp.sqrt(jnp.dot(x, x))


def ref_qr_panel(a):
    """One Householder panel step of DGEQR2 on column 0 (LAPACK
    conventions): returns the updated matrix (beta on the diagonal, v tail
    below it, trailing columns reflected) and tau.
    """
    m = a.shape[0]
    x = a[:, 0]
    alpha = x[0]
    norm_tail = jnp.sqrt(jnp.sum(x[1:] ** 2))
    sigma = jnp.sqrt(alpha**2 + norm_tail**2)
    beta = jnp.where(alpha >= 0, -sigma, sigma)
    safe = norm_tail > 0
    tau = jnp.where(safe, (beta - alpha) / beta, 0.0)
    scale = jnp.where(safe, 1.0 / (alpha - beta), 0.0)
    v = jnp.concatenate([jnp.ones((1,), a.dtype), x[1:] * scale])
    # Apply (I - tau v v^T) to the whole panel.
    w = v @ a
    out = a - tau * jnp.outer(v, w)
    # Column 0: beta at the top, v tail stored below the diagonal.
    col0 = jnp.concatenate([jnp.where(safe, beta, alpha)[None], v[1:]])
    out = out.at[:, 0].set(col0)
    return out, tau
