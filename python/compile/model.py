"""L2 — the JAX compute graph of the system's BLAS operators, built on the
L1 Pallas kernels. These are the functions ``aot.py`` lowers once per shape
into ``artifacts/*.hlo.txt`` for the Rust runtime; Python never runs on the
request path.

Every public function returns a tuple (lowered with ``return_tuple=True``),
matching the Rust side's ``to_tuple1``/``to_tuple2`` unwrapping.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels.gemm_block import block_gemm  # noqa: E402
from .kernels.gemv import strip_gemv  # noqa: E402
from .kernels.level1 import chunked_axpy, chunked_dot  # noqa: E402


def dgemm(a, b, c):
    """C' = A @ B + C via the blocked Pallas kernel."""
    return (block_gemm(a, b, c),)


def dgemv(a, x, y):
    """y' = A @ x + y via the strip Pallas kernel."""
    return (strip_gemv(a, x, y),)


def ddot(x, y):
    """x . y via the chunked Pallas reduction."""
    return (chunked_dot(x, y),)


def daxpy(alpha, x, y):
    """alpha x + y (alpha is a runtime scalar operand)."""
    return (chunked_axpy(alpha, x, y),)


def dnrm2(x):
    """||x||_2 = sqrt(ddot(x, x)) — fig 3's 'ddot plus a square root'."""
    return (jnp.sqrt(chunked_dot(x, x)),)


def qr_panel(a):
    """One DGEQR2 Householder panel step (the Fig-1 DGEMV-bound inner
    operation): reflector from column 0, trailing update through the Pallas
    GEMM kernel (rank-1 as (m×1)·(1×p)). Returns (updated A, tau)."""
    m = a.shape[0]
    x = a[:, 0]
    alpha = x[0]
    norm_tail = jnp.sqrt(jnp.sum(x[1:] ** 2))
    sigma = jnp.sqrt(alpha**2 + norm_tail**2)
    beta = jnp.where(alpha >= 0, -sigma, sigma)
    safe = norm_tail > 0
    tau = jnp.where(safe, (beta - alpha) / beta, 0.0)
    scale = jnp.where(safe, 1.0 / (alpha - beta), 0.0)
    v = jnp.concatenate([jnp.ones((1,), a.dtype), x[1:] * scale])
    # w = v^T A via the strip-GEMV kernel (A^T @ v), then the rank-1 update
    # via the blocked GEMM kernel: A - (tau v) @ w^T.
    w = strip_gemv(a.T, v, jnp.zeros((a.shape[1],), a.dtype))
    out = block_gemm((-tau * v)[:, None], w[None, :], a, tile=1)
    col0 = jnp.concatenate([jnp.where(safe, beta, alpha)[None], v[1:]])
    out = out.at[:, 0].set(col0)
    return out, tau


#: Operator registry: name → (builder of example args from n, function).
def example_args(op: str, n: int):
    """Example ShapeDtypeStructs for lowering `op` at size n."""
    f64 = jnp.float64
    mat = jax.ShapeDtypeStruct((n, n), f64)
    vec = jax.ShapeDtypeStruct((n,), f64)
    scalar = jax.ShapeDtypeStruct((), f64)
    match op:
        case "gemm":
            return (mat, mat, mat)
        case "gemv":
            return (mat, vec, vec)
        case "dot":
            return (vec, vec)
        case "axpy":
            return (scalar, vec, vec)
        case "nrm2":
            return (vec,)
        case "qr_panel":
            return (mat,)
        case _:
            raise ValueError(f"unknown op {op}")


OPS = {
    "gemm": dgemm,
    "gemv": dgemv,
    "dot": ddot,
    "axpy": daxpy,
    "nrm2": dnrm2,
    "qr_panel": qr_panel,
}
