"""AOT compile path: lower every L2 operator at every needed shape to HLO
**text** in ``artifacts/`` for the Rust PJRT runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids, which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from ``python/``):  python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

#: (op, sizes) lowered by default. GEMM/GEMV cover the paper's table sizes
#: (§4.5.1) plus a quickstart size 8; Level-1 ops cover typical vector
#: lengths; qr_panel serves the QR example.
DEFAULT_PLAN = [
    ("gemm", [8, 20, 40, 60, 80, 100]),
    ("gemv", [8, 20, 40, 60, 80, 100]),
    ("dot", [64, 256, 1024]),
    ("axpy", [64, 256, 1024]),
    ("nrm2", [64, 256, 1024]),
    ("qr_panel", [32, 96]),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the text
    parser on the Rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_op(op: str, n: int) -> str:
    fn = model.OPS[op]
    args = model.example_args(op, n)
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--ops", default="", help="comma list (default: all)")
    ap.add_argument(
        "--force", action="store_true", help="rebuild even if artifacts exist"
    )
    ns = ap.parse_args()
    os.makedirs(ns.out, exist_ok=True)
    wanted = set(filter(None, ns.ops.split(",")))

    manifest = []
    for op, sizes in DEFAULT_PLAN:
        if wanted and op not in wanted:
            continue
        for n in sizes:
            path = os.path.join(ns.out, f"{op}_n{n}.hlo.txt")
            manifest.append(os.path.basename(path))
            if os.path.exists(path) and not ns.force:
                print(f"keep  {path}")
                continue
            text = lower_op(op, n)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(ns.out, "MANIFEST"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
