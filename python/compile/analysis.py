"""L1 kernel structure analysis: VMEM footprint and MXU-utilization
estimates from the BlockSpecs (DESIGN.md §Perf, L1).

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the L1
performance deliverable is *structural*: per kernel and shape, how many
bytes each grid step keeps resident in VMEM (must fit the ~16 MiB/core
budget with headroom for double buffering) and what fraction of an MXU-
aligned tile the inner dot occupies. The pytest suite asserts the
invariants; `python -m compile.analysis` prints the table recorded in
EXPERIMENTS.md §Perf.
"""

import jax

jax.config.update("jax_enable_x64", True)

from dataclasses import dataclass  # noqa: E402

from .kernels.gemm_block import _pick_tile  # noqa: E402

#: Bytes per element (artifacts are f64).
ELEM = 8
#: TPU VMEM budget per core (v4-class), bytes.
VMEM_BUDGET = 16 * 1024 * 1024
#: MXU systolic tile edge.
MXU = 128


@dataclass
class KernelEstimate:
    """Structural estimate for one kernel instantiation."""

    kernel: str
    shape: str
    grid: tuple
    vmem_bytes: int  # resident blocks per grid step (single-buffered)
    vmem_pipelined: int  # with Pallas double-buffering (2x inputs)
    mxu_rows: float  # fraction of the MXU tile the inner dot fills
    flops_per_byte: float  # arithmetic intensity of one grid step

    def fits(self) -> bool:
        return self.vmem_pipelined <= VMEM_BUDGET


def gemm_estimate(m: int, p: int, k: int, tile: int | None = None) -> KernelEstimate:
    tm = tile or _pick_tile(m)
    tp = tile or _pick_tile(p)
    tk = tile or _pick_tile(k)
    grid = (m // tm, p // tp, k // tk)
    # Per step: A (tm×tk), B (tk×tp), C seed (tm×tp), out accumulator.
    inputs = (tm * tk + tk * tp + tm * tp) * ELEM
    out = tm * tp * ELEM
    flops = 2 * tm * tp * tk
    return KernelEstimate(
        kernel="block_gemm",
        shape=f"{m}x{p}x{k}/t{tm}",
        grid=grid,
        vmem_bytes=inputs + out,
        vmem_pipelined=2 * inputs + out,
        mxu_rows=min(tm, MXU) * min(tp, MXU) / (MXU * MXU),
        flops_per_byte=flops / (inputs + out),
    )


def gemv_estimate(m: int, n: int, strip: int = 16) -> KernelEstimate:
    grid = (m // strip,)
    inputs = (strip * n + n + strip) * ELEM
    out = strip * ELEM
    flops = 2 * strip * n
    return KernelEstimate(
        kernel="strip_gemv",
        shape=f"{m}x{n}/s{strip}",
        grid=grid,
        vmem_bytes=inputs + out,
        vmem_pipelined=2 * inputs + out,
        mxu_rows=min(strip, MXU) / MXU,
        flops_per_byte=flops / (inputs + out),
    )


def dot_estimate(n: int, chunk: int = 64) -> KernelEstimate:
    grid = (n // chunk,)
    inputs = 2 * chunk * ELEM
    return KernelEstimate(
        kernel="chunked_dot",
        shape=f"n{n}/c{chunk}",
        grid=grid,
        vmem_bytes=inputs + ELEM,
        vmem_pipelined=2 * inputs + ELEM,
        mxu_rows=0.0,  # VPU reduction, not MXU
        flops_per_byte=2 * chunk / (inputs + ELEM),
    )


def standard_table() -> list[KernelEstimate]:
    """The estimates recorded in EXPERIMENTS.md §Perf."""
    rows = []
    for n in (20, 40, 60, 80, 100):
        rows.append(gemm_estimate(n, n, n))
    rows.append(gemm_estimate(1024, 1024, 1024, tile=128))  # production shape
    for n in (100, 1024):
        rows.append(gemv_estimate(n if n % 16 == 0 else 100, n, strip=4 if n == 100 else 16))
    rows.append(dot_estimate(1024))
    return rows


def main() -> None:
    print(f"{'kernel':<14} {'shape':<16} {'grid':<14} {'VMEM(dbuf)':>12} "
          f"{'MXU fill':>9} {'flops/B':>8} {'fits':>5}")
    for e in standard_table():
        print(
            f"{e.kernel:<14} {e.shape:<16} {str(e.grid):<14} "
            f"{e.vmem_pipelined:>12} {e.mxu_rows:>9.3f} {e.flops_per_byte:>8.2f} "
            f"{str(e.fits()):>5}"
        )


if __name__ == "__main__":
    main()
