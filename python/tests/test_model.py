"""L2 correctness: the model graph (operators + QR panel composition) vs
numpy, and the operator registry's example-argument shapes."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(77)


def test_dgemm_tuple_out():
    n = 12
    a = jnp.asarray(RNG.standard_normal((n, n)))
    (out,) = model.dgemm(a, a, a)
    np.testing.assert_allclose(out, a @ a + a, rtol=1e-12)


def test_dgemv_and_level1():
    n = 64
    a = jnp.asarray(RNG.standard_normal((n, n)))
    x = jnp.asarray(RNG.standard_normal(n))
    y = jnp.asarray(RNG.standard_normal(n))
    np.testing.assert_allclose(model.dgemv(a, x, y)[0], a @ x + y, rtol=1e-12)
    np.testing.assert_allclose(model.ddot(x, y)[0], float(x @ y), rtol=1e-12)
    np.testing.assert_allclose(model.daxpy(2.0, x, y)[0], 2.0 * x + y, rtol=1e-12)
    np.testing.assert_allclose(
        model.dnrm2(x)[0], float(jnp.sqrt(x @ x)), rtol=1e-12
    )


def test_qr_panel_matches_ref():
    n = 16
    a = jnp.asarray(RNG.standard_normal((n, n)))
    out, tau = model.qr_panel(a)
    wout, wtau = ref.ref_qr_panel(a)
    np.testing.assert_allclose(out, wout, rtol=1e-11, atol=1e-11)
    np.testing.assert_allclose(tau, wtau, rtol=1e-12)


def test_qr_panel_annihilates_column():
    """After the panel step, applying the stored reflector to the original
    column must yield (beta, 0, ..., 0) — the Householder invariant."""
    n = 12
    a = jnp.asarray(RNG.standard_normal((n, n)))
    out, tau = model.qr_panel(a)
    v = jnp.concatenate([jnp.ones((1,)), out[1:, 0]])
    x = a[:, 0]
    reflected = x - tau * v * (v @ x)
    np.testing.assert_allclose(reflected[0], out[0, 0], rtol=1e-11)
    np.testing.assert_allclose(reflected[1:], jnp.zeros(n - 1), atol=1e-11)


def test_qr_panel_zero_tail_is_safe():
    a = jnp.eye(8, dtype=jnp.float64)
    out, tau = model.qr_panel(a)
    assert float(tau) == 0.0
    np.testing.assert_allclose(out[:, 0], a[:, 0])


@pytest.mark.parametrize("op", list(model.OPS))
def test_example_args_shapes(op):
    args = model.example_args(op, 8)
    assert isinstance(args, tuple) and len(args) >= 1
    # Lowerability is checked in test_aot; here just shape sanity.
    for s in args:
        assert s.dtype == jnp.float64


def test_example_args_unknown_op():
    with pytest.raises(ValueError):
        model.example_args("cholesky", 8)
