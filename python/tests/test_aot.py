"""AOT path: every operator lowers to parseable HLO text with the right
entry signature, and the emitted file round-trips through the naming
convention the Rust runtime expects."""

import os

import pytest

from compile import aot, model


@pytest.mark.parametrize("op,n", [("gemm", 8), ("gemv", 8), ("dot", 64), ("axpy", 64), ("nrm2", 64), ("qr_panel", 32)])
def test_lower_op_produces_hlo_text(op, n):
    text = aot.lower_op(op, n)
    assert text.startswith("HloModule"), text[:60]
    assert "f64" in text, "artifacts must be double precision"
    # return_tuple=True: the root is a tuple.
    assert "ROOT" in text


def test_gemm_entry_layout_mentions_shapes():
    text = aot.lower_op("gemm", 8)
    assert "f64[8,8]" in text


def test_plan_covers_paper_sizes():
    plan = dict(aot.DEFAULT_PLAN)
    for n in [20, 40, 60, 80, 100]:
        assert n in plan["gemm"], f"paper size {n} missing from gemm plan"
        assert n in plan["gemv"], f"paper size {n} missing from gemv plan"


def test_write_and_manifest(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "arts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--ops", "dot"],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr
    assert (out / "dot_n64.hlo.txt").exists()
    manifest = (out / "MANIFEST").read_text().split()
    assert "dot_n64.hlo.txt" in manifest


def test_ops_registry_complete():
    assert set(model.OPS) == {"gemm", "gemv", "dot", "axpy", "nrm2", "qr_panel"}
