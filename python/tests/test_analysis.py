"""L1 structural invariants: VMEM budgets and tiling sanity (the §Perf
acceptance criteria for the kernel layer)."""

import pytest

from compile.analysis import (
    VMEM_BUDGET,
    dot_estimate,
    gemm_estimate,
    gemv_estimate,
    standard_table,
)


def test_all_standard_shapes_fit_vmem():
    for e in standard_table():
        assert e.fits(), f"{e.kernel} {e.shape} needs {e.vmem_pipelined} bytes"


def test_paper_sizes_are_tiny_in_vmem():
    # The paper's 100x100 problem is ~0.23 MB — trivially resident; the
    # kernel structure (not capacity) is what the experiments exercise.
    e = gemm_estimate(100, 100, 100)
    assert e.vmem_pipelined < VMEM_BUDGET // 10


def test_production_tile_is_mxu_aligned():
    e = gemm_estimate(1024, 1024, 1024, tile=128)
    assert e.mxu_rows == 1.0, "128-tile must fill the MXU"
    assert e.fits()
    # Arithmetic intensity of a 128³ step: 2·128³ / (4·128²·8) = 8 flops/B.
    assert 6 < e.flops_per_byte < 10


def test_intensity_grows_with_tile():
    small = gemm_estimate(64, 64, 64, tile=8).flops_per_byte
    large = gemm_estimate(64, 64, 64, tile=32).flops_per_byte
    assert large > small


def test_gemv_is_low_intensity():
    e = gemv_estimate(100, 100, strip=4)
    assert e.flops_per_byte < 3, "GEMV must be bandwidth-bound"


def test_dot_is_lowest_intensity():
    e = dot_estimate(1024)
    assert e.flops_per_byte < 1.0


def test_grid_covers_problem():
    e = gemm_estimate(40, 40, 40)
    gm, gp, gk = e.grid
    tile = int(e.shape.split("/t")[1])
    assert gm * tile == 40 and gp * tile == 40 and gk * tile == 40


@pytest.mark.parametrize("n", [20, 40, 60, 80, 100])
def test_paper_sizes_pick_reasonable_tiles(n):
    e = gemm_estimate(n, n, n)
    tile = int(e.shape.split("/t")[1])
    assert n % tile == 0 and tile >= 4
