"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, including
Hypothesis sweeps over shapes, dtypes and data — the core correctness
signal of the compile path."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gemm_block import _pick_tile, block_gemm
from compile.kernels.gemv import strip_gemv
from compile.kernels.level1 import chunked_axpy, chunked_dot

RNG = np.random.default_rng(1234)


def randmat(m, n, dtype=np.float64):
    return jnp.asarray(RNG.standard_normal((m, n)).astype(dtype))


def randvec(n, dtype=np.float64):
    return jnp.asarray(RNG.standard_normal(n).astype(dtype))


def tol(dtype):
    return 1e-12 if dtype == np.float64 else 1e-4


# ---------------------------------------------------------------- GEMM


@pytest.mark.parametrize("n", [4, 8, 20, 40, 60])
@pytest.mark.parametrize("dtype", [np.float64, np.float32])
def test_gemm_square(n, dtype):
    a, b, c = randmat(n, n, dtype), randmat(n, n, dtype), randmat(n, n, dtype)
    got = block_gemm(a, b, c)
    np.testing.assert_allclose(got, ref.ref_gemm(a, b, c), rtol=tol(dtype), atol=tol(dtype))


@pytest.mark.parametrize("m,p,k", [(8, 12, 20), (4, 4, 40), (24, 8, 8), (12, 20, 4)])
def test_gemm_rectangular(m, p, k):
    a, b, c = randmat(m, k), randmat(k, p), randmat(m, p)
    got = block_gemm(a, b, c)
    np.testing.assert_allclose(got, ref.ref_gemm(a, b, c), rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("tile", [1, 2, 4, 5, 10, 20])
def test_gemm_explicit_tiles(tile):
    n = 20
    a, b, c = randmat(n, n), randmat(n, n), randmat(n, n)
    got = block_gemm(a, b, c, tile=tile)
    np.testing.assert_allclose(got, ref.ref_gemm(a, b, c), rtol=1e-12, atol=1e-12)


def test_gemm_identity():
    n = 16
    a = randmat(n, n)
    got = block_gemm(a, jnp.eye(n, dtype=a.dtype), jnp.zeros((n, n), a.dtype))
    np.testing.assert_allclose(got, a, rtol=0, atol=0)


def test_gemm_accumulates_c():
    n = 8
    a = jnp.zeros((n, n), jnp.float64)
    c = randmat(n, n)
    got = block_gemm(a, a, c)
    np.testing.assert_allclose(got, c, rtol=0, atol=0)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 12),
    p=st.integers(1, 12),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31),
)
def test_gemm_hypothesis_shapes(m, p, k, seed):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.standard_normal((m, k)))
    b = jnp.asarray(r.standard_normal((k, p)))
    c = jnp.asarray(r.standard_normal((m, p)))
    got = block_gemm(a, b, c)
    np.testing.assert_allclose(got, ref.ref_gemm(a, b, c), rtol=1e-11, atol=1e-11)


def test_pick_tile_divides():
    for n in range(1, 130):
        t = _pick_tile(n)
        assert n % t == 0 and 1 <= t <= 32


# ---------------------------------------------------------------- GEMV


@pytest.mark.parametrize("n", [4, 20, 60, 100])
def test_gemv_square(n):
    a, x, y = randmat(n, n), randvec(n), randvec(n)
    np.testing.assert_allclose(
        strip_gemv(a, x, y), ref.ref_gemv(a, x, y), rtol=1e-12, atol=1e-12
    )


@pytest.mark.parametrize("m,n", [(8, 20), (20, 8), (4, 100)])
def test_gemv_rectangular(m, n):
    a, x, y = randmat(m, n), randvec(n), randvec(m)
    np.testing.assert_allclose(
        strip_gemv(a, x, y), ref.ref_gemv(a, x, y), rtol=1e-12, atol=1e-12
    )


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 32), n=st.integers(1, 32), seed=st.integers(0, 2**31))
def test_gemv_hypothesis(m, n, seed):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.standard_normal((m, n)))
    x = jnp.asarray(r.standard_normal(n))
    y = jnp.asarray(r.standard_normal(m))
    np.testing.assert_allclose(
        strip_gemv(a, x, y), ref.ref_gemv(a, x, y), rtol=1e-11, atol=1e-11
    )


# ---------------------------------------------------------------- Level-1


@pytest.mark.parametrize("n", [1, 4, 64, 257, 1024])
def test_dot_sizes(n):
    x, y = randvec(n), randvec(n)
    np.testing.assert_allclose(chunked_dot(x, y), ref.ref_dot(x, y), rtol=1e-12)


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
def test_dot_dtypes(dtype):
    x, y = randvec(128, dtype), randvec(128, dtype)
    np.testing.assert_allclose(chunked_dot(x, y), ref.ref_dot(x, y), rtol=tol(dtype))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 300), seed=st.integers(0, 2**31))
def test_dot_hypothesis(n, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal(n))
    y = jnp.asarray(r.standard_normal(n))
    np.testing.assert_allclose(chunked_dot(x, y), ref.ref_dot(x, y), rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("n", [4, 64, 100])
@pytest.mark.parametrize("alpha", [0.0, 1.0, -2.5])
def test_axpy(n, alpha):
    x, y = randvec(n), randvec(n)
    np.testing.assert_allclose(
        chunked_axpy(alpha, x, y), ref.ref_axpy(alpha, x, y), rtol=1e-12
    )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 200), alpha=st.floats(-10, 10), seed=st.integers(0, 2**31))
def test_axpy_hypothesis(n, alpha, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal(n))
    y = jnp.asarray(r.standard_normal(n))
    np.testing.assert_allclose(
        chunked_axpy(alpha, x, y), ref.ref_axpy(alpha, x, y), rtol=1e-10, atol=1e-10
    )
