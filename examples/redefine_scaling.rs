//! Fig 12: speed-up of parallel DGEMM on REDEFINE tile arrays of 2×2, 3×3
//! and 4×4 over the single-PE realization, across matrix sizes.
//!
//! Run: `cargo run --release --example redefine_scaling`

use redefine_blas::noc::parallel_dgemm;
use redefine_blas::pe::AeLevel;
use redefine_blas::util::Mat;

fn main() {
    println!("Fig 12: REDEFINE speed-up over single PE (AE5 tiles)\n");
    println!("{:<8} {:>10} {:>10} {:>10}", "n", "2x2", "3x3", "4x4");
    // n must divide by every b in {2,3,4} → multiples of 12.
    for n in [24usize, 48, 60, 96, 120] {
        let a = Mat::random(n, n, 301);
        let b = Mat::random(n, n, 302);
        let c = Mat::random(n, n, 303);
        let mut row = format!("{n:<8}");
        for bb in [2usize, 3, 4] {
            let r = parallel_dgemm(n, bb, AeLevel::Ae5, &a, &b, &c);
            row.push_str(&format!(" {:>9.2}x", r.speedup()));
        }
        println!("{row}");
    }
    println!("\npaper: speed-up approaches 4 / 9 / 16 as n grows; for small n");
    println!("communication with the memory column dominates (§5.5).");
}
