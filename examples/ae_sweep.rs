//! Architectural-enhancement sweep: regenerates Tables 4–9 of the paper
//! (DGEMM latency / CPF / Gflops-per-watt at every enhancement level for
//! the paper's five matrix sizes) and prints measured-vs-paper side by side.
//!
//! Run: `cargo run --release --example ae_sweep`

use redefine_blas::metrics::{gemm_sweep, PAPER_SIZES};
use redefine_blas::pe::AeLevel;

/// Paper latencies (Tables 4–9), rows = AE0..AE5, cols = 20..100.
pub const PAPER_LATENCY: [[u64; 5]; 6] = [
    [39_000, 310_075, 1_040_754, 2_457_600, 4_770_000],
    [23_000, 178_471, 595_421, 1_410_662, 2_730_365],
    [15_251, 113_114, 371_699, 877_124, 1_696_921],
    [12_745, 97_136, 324_997, 784_838, 1_519_083],
    [7_079, 52_624, 174_969, 422_924, 818_178],
    [5_561, 38_376, 124_741, 298_161, 573_442],
];

/// Paper Gflops/W (Tables 4–9).
pub const PAPER_GFLOPS_W: [[f64; 5]; 6] = [
    [16.66, 16.87, 17.15, 17.25, 17.38],
    [14.87, 15.53, 15.77, 15.81, 15.98],
    [10.52, 11.49, 11.85, 11.93, 12.06],
    [12.59, 13.38, 13.56, 13.33, 13.47],
    [22.67, 24.71, 25.19, 24.95, 25.02],
    [28.86, 33.88, 35.33, 35.11, 35.70],
];

fn main() {
    println!("DGEMM enhancement sweep (paper Tables 4-9)\n");
    let sweep = gemm_sweep(&PAPER_SIZES);

    for (ai, row) in sweep.iter().enumerate() {
        let ae = AeLevel::ALL[ai];
        println!("=== {} — paper table {} ===", ae, 4 + ai);
        println!(
            "{:<10} {:>12} {:>12} {:>7} {:>8} {:>8} {:>9} {:>9}",
            "n", "cycles", "paper", "ratio", "CPF", "paperCPF", "Gfl/W", "paper"
        );
        for (si, m) in row.iter().enumerate() {
            let paper = PAPER_LATENCY[ai][si];
            let paper_cpf = paper as f64 / (3 * PAPER_SIZES[si].pow(3)) as f64;
            println!(
                "{:<10} {:>12} {:>12} {:>7.3} {:>8.3} {:>8.3} {:>9.2} {:>9.2}",
                format!("{0}x{0}", PAPER_SIZES[si]),
                m.latency(),
                paper,
                m.latency() as f64 / paper as f64,
                m.paper_cpf(),
                paper_cpf,
                m.gflops_per_watt(),
                PAPER_GFLOPS_W[ai][si],
            );
        }
        println!();
    }

    // Fig 11(a) headline: total speed-up AE0 → AE5.
    println!("=== Fig 11(a): AE0->AE5 speed-up (paper: 7x / 8.13x / 8.34x at 20/40/60) ===");
    for (si, &n) in PAPER_SIZES.iter().enumerate() {
        let s = sweep[0][si].latency() as f64 / sweep[5][si].latency() as f64;
        let p = PAPER_LATENCY[0][si] as f64 / PAPER_LATENCY[5][si] as f64;
        println!("  n={n:<4} measured {s:>6.2}x   paper {p:>6.2}x");
    }
}
