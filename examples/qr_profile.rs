//! Fig 1: where the time goes in QR factorization.
//!
//! DGEQR2 (unblocked) is ~99% matrix-vector work (DGEMV + DGER); DGEQRF
//! (blocked) is ~99% DGEMM — the observation that motivates accelerating
//! BLAS in the first place. This example reproduces the profile with the
//! flop-attribution profiler over our LAPACK-lite.
//!
//! The same profile is no longer just a host-side motivation plot: every
//! factorization served end to end (`redefine serve --lapack qr|lu|chol`)
//! expands into a dependency DAG of cached BLAS kernels and carries this
//! `FlopProfile` in its response (`FactorOutcome::profile`), so the Fig-1
//! attribution is pinned on the serving path too (`tests/lapack_serve.rs`).
//!
//! Run: `cargo run --release --example qr_profile`

use redefine_blas::lapack::{dgeqr2_profiled, dgeqrf_profiled, dgetrf, dpotrf};
use redefine_blas::util::Mat;

fn main() {
    let n = 256; // the paper profiles 10k×10k; the shares stabilize long before
    let a = Mat::random(n, n, 401);

    let (_, p2) = dgeqr2_profiled(&a);
    println!("{}", p2.report(&format!("DGEQR2 {n}x{n} (paper fig 1: ~99% DGEMV-class)")));

    let (_, pf) = dgeqrf_profiled(&a, 32);
    println!("{}", pf.report(&format!("DGEQRF {n}x{n}, nb=32 (paper fig 1: ~99% DGEMM)")));

    let spd = Mat::random_spd(128, 402);
    let (_, pl) = dgetrf(&spd);
    println!("{}", pl.report("DGETRF 128x128 (XGETRF of §1)"));

    let (_, pc) = dpotrf(&spd);
    println!("{}", pc.report("DPOTRF 128x128 (XPBTRF-class of §1)"));
}
