//! End-to-end driver: the full system on a real workload.
//!
//! Serves a batched mixed BLAS request stream (DGEMM / DGEMV / DDOT, the
//! request mix a factorization-heavy client generates) through the L3
//! coordinator: values come from the AOT XLA artifacts where shapes match,
//! timing and energy from the cycle-accurate PE + REDEFINE NoC simulators.
//! Reports per-op latency distribution, simulated throughput, energy
//! efficiency, and cross-checks every result against host BLAS.
//!
//! This is the deliverable-(e) driver recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use redefine_blas::blas;
use redefine_blas::coordinator::{request::Request, Coordinator, CoordinatorConfig, ValueSource};
use redefine_blas::pe::{AeLevel, PeConfig};
use redefine_blas::util::{rel_fro_error, Mat, XorShift64};

fn main() {
    let ae = AeLevel::Ae5;
    let cfg = CoordinatorConfig {
        ae,
        b: 2,
        artifact_dir: "artifacts".into(),
        verify: true,
        ..CoordinatorConfig::default()
    };
    let mut co = Coordinator::new(cfg);
    println!(
        "end-to-end: 2x2 REDEFINE array, {ae}, XLA value path: {}",
        co.has_xla()
    );

    // Build a deterministic 48-request workload biased to artifact shapes
    // (so the XLA path is exercised) plus off-shape sizes (PE-sim fallback).
    let mut rng = XorShift64::new(2026);
    let mut reqs = Vec::new();
    let art_sizes = [8usize, 20, 40, 60, 80, 100];
    for i in 0..48 {
        match i % 3 {
            0 => {
                let n = art_sizes[rng.below(art_sizes.len())];
                reqs.push(Request::RandomDgemm { n, seed: 9000 + i as u64 });
            }
            1 => {
                let n = if i % 6 == 1 { 20 } else { 36 }; // artifact + off-shape
                let a = Mat::random(n, n, 9100 + i as u64);
                let x = rng.vec(n);
                let y = rng.vec(n);
                reqs.push(Request::Dgemv { a, x, y });
            }
            _ => {
                let n = [64usize, 256, 100][rng.below(3)];
                let x = rng.vec(n);
                let y = rng.vec(n);
                reqs.push(Request::Ddot { x, y });
            }
        }
    }

    // Golden check inputs: recompute a couple of requests by hand later.
    let t0 = std::time::Instant::now();
    let resps = co.serve(reqs);
    let wall = t0.elapsed();

    let pe_cfg = PeConfig::paper(ae);
    let mut per_op: std::collections::BTreeMap<&str, (usize, u64, usize)> =
        std::collections::BTreeMap::new();
    let mut total_cycles = 0u64;
    let mut xla_hits = 0usize;
    for r in &resps {
        let e = per_op.entry(r.op).or_insert((0, 0, 0));
        e.0 += 1;
        e.1 += r.cycles;
        if r.source == ValueSource::Xla {
            e.2 += 1;
            xla_hits += 1;
        }
        total_cycles += r.cycles;
    }

    println!("\nserved {} requests in {:.1} ms wall", resps.len(), wall.as_secs_f64() * 1e3);
    println!(
        "simulated time: {:.3} ms @0.2 GHz ({} cycles), {} / {} answered from XLA artifacts",
        total_cycles as f64 * pe_cfg.cycle_ns() / 1e6,
        total_cycles,
        xla_hits,
        resps.len()
    );
    println!("\n{:<8} {:>6} {:>14} {:>12} {:>10}", "op", "count", "total cycles", "avg cycles", "xla hits");
    for (op, (count, cyc, xla)) in &per_op {
        println!(
            "{:<8} {:>6} {:>14} {:>12} {:>10}",
            op,
            count,
            cyc,
            cyc / *count as u64,
            xla
        );
    }

    // Spot numerical audit: replay one dgemm request independently.
    let n = 40;
    let a = Mat::random(n, n, 1234);
    let b = Mat::random(n, n, 1235);
    let c = Mat::zeros(n, n);
    let r = co.dgemm(&a, &b, &c);
    let want = blas::level3::dgemm_ref(&a, &b, &c);
    let err = rel_fro_error(r.c.as_slice(), want.as_slice());
    println!("\naudit dgemm n=40: source={:?}, rel err = {err:.2e}", r.source);
    assert!(err < 1e-12);
    assert!(xla_hits > 0 || !co.has_xla(), "artifact shapes should hit the XLA path");
    println!("end_to_end OK");
}
