//! PJRT smoke test: load one AOT artifact (gemm_n8), execute it on the
//! CPU PJRT client, and verify the numerics against host BLAS — the
//! smallest possible proof that the L2→L3 bridge works.
//!
//! Run: `make artifacts && cargo run --release --features pjrt,xla-rt --example rt_smoke`
//! (`pjrt` alone builds the offline stub; the real client needs `xla-rt`
//! plus the vendored `xla` crate — see rust/Cargo.toml.)

use redefine_blas::runtime::Runtime;
use redefine_blas::util::Mat;
fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rt = Runtime::new("artifacts")?;
    println!("platform={} artifacts={:?}", rt.platform(), rt.available().len());
    let a = Mat::random(8, 8, 1);
    let b = Mat::random(8, 8, 2);
    let c = Mat::random(8, 8, 3);
    let got = rt.gemm(&a, &b, &c)?;
    let want = redefine_blas::blas::level3::dgemm_ref(&a, &b, &c);
    let err = redefine_blas::util::rel_fro_error(got.as_slice(), want.as_slice());
    println!("gemm_n8 rel err = {err:e}");
    assert!(err < 1e-12);
    println!("XLA round trip OK");
    Ok(())
}
