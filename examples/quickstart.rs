//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load an AOT-compiled XLA artifact (built once by `make artifacts`).
//! 2. Run DGEMM through the coordinator: values from the artifact (PJRT),
//!    timing/energy from the cycle-accurate PE + NoC simulators.
//! 3. Cross-check against the host reference BLAS.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use redefine_blas::blas::level3::dgemm_ref;
use redefine_blas::coordinator::{Coordinator, CoordinatorConfig};
use redefine_blas::pe::{AeLevel, PeConfig};
use redefine_blas::util::{rel_fro_error, Mat};

fn main() {
    let n = 8; // shipped artifact size — see python/compile/aot.py
    let a = Mat::random(n, n, 11);
    let b = Mat::random(n, n, 12);
    let c = Mat::random(n, n, 13);

    let mut co = Coordinator::new(CoordinatorConfig {
        ae: AeLevel::Ae5,
        b: 2,
        artifact_dir: "artifacts".into(),
        verify: true,
        ..CoordinatorConfig::default()
    });
    println!("XLA value path live: {}", co.has_xla());
    if co.has_xla() {
        println!("artifacts: {:?}", co.artifacts().len());
    }

    let r = co.dgemm(&a, &b, &c);
    let want = dgemm_ref(&a, &b, &c);
    let err = rel_fro_error(r.c.as_slice(), want.as_slice());

    let cfg = PeConfig::paper(AeLevel::Ae5);
    println!("dgemm n={n}: source={:?}, rel err vs host BLAS = {err:.3e}", r.source);
    println!(
        "simulated: {} cycles on a 2x2 REDEFINE array ({} PE tiles), {:.3} Gflops @0.2 GHz, {:.3e} J",
        r.makespan,
        r.tiles.len(),
        r.gflops(n, &cfg),
        r.energy_j
    );
    assert!(err < 1e-12);
    println!("quickstart OK");
}
